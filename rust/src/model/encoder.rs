//! CPU reference transformer encoder with in-block token merging, built
//! around a reusable allocation-free scratch workspace.
//!
//! Numerically mirrors `python/compile/model.py::encoder_forward`; the
//! parity is asserted against `artifacts/testvectors.json` (trained ViT
//! logits) and used for the r-sweep experiments where compiling one HLO
//! artifact per (mode, r) point would be wasteful.
//!
//! # The `EncoderScratch` workspace
//!
//! Every buffer the forward pass needs — the pre-LN output, the Q/K/V
//! projections, the head-major packed K tile, the per-head (n, n) score
//! tile, the attention output, the
//! MLP hidden state, and the merge step's Gram/normalization/plan/output
//! buffers (including the plan builders' index vectors, via
//! [`PlanScratch`](crate::merge::PlanScratch) and the in-place
//! [`MergePlan`](crate::merge::MergePlan)) — lives in one
//! [`EncoderScratch`].  The buffers are reshaped in place as the token
//! count shrinks layer by layer
//! ([`Mat::reshape`](crate::tensor::Mat::reshape) never gives capacity
//! back), so once a scratch has seen its largest shape, a steady-state
//! forward performs **zero heap allocations** across the whole layer
//! loop — attention, MLP, *and* every merge mode (asserted for all ten
//! modes by `tests/alloc_free.rs` via the
//! [`CountingAllocator`](crate::util::alloc::CountingAllocator) hook).
//!
//! ## Ownership and reuse rules
//!
//! * A scratch is **per worker thread**, never shared: it is `Send` but
//!   deliberately exposes no synchronized access.  Serial callers own one
//!   and pass `&mut` ([`encoder_forward_scratch`]); the batch driver keeps
//!   one per worker in a [`ScratchPool`] and hands chunk `i` of the batch
//!   to scratch `i`
//!   ([`parallel_map_mut_ctx`](crate::merge::batch::parallel_map_mut_ctx)).
//! * Reuse across **layers, samples, and requests** is always safe: every
//!   op fully overwrites (or zero-resets) the region it reads back, so no
//!   state leaks between uses.  The property tests in
//!   `tests/prop_encoder.rs` assert a reused scratch matches a fresh one
//!   across all merge modes and shapes.
//! * Long-lived servers should keep the pool alive across requests (the
//!   coordinator's CPU workers do, via [`crate::engine::Session`] — see
//!   `coordinator/batcher.rs`); the allocating one-shot entry point
//!   ([`encoder_forward`]) remains, so the python-parity contract is
//!   unchanged.
//!
//! # Entry points
//!
//! The owning API is [`crate::engine::Engine`] → [`crate::engine::Session`]:
//! a session holds the resolved weights, a scratch pool, pooled input
//! [`SeqSlot`]s, and a pooled output buffer per sample, so a whole warmed
//! request — final LayerNorm and batch outputs included — allocates
//! nothing.  This module provides the shared cores the session (and the
//! deprecated free-function wrappers) drive:
//! * [`encoder_forward_slots`] — batch of pre-filled slots fanned out
//!   over scoped worker threads, each worker reusing its own scratch for
//!   every sample (and layer) it processes.  Per-(layer, sample) RNG
//!   seeding keeps stochastic modes reproducible under any thread
//!   schedule; deterministic modes match the serial path exactly.
//! * [`encoder_forward_slot`] — one slot under the serial shared-RNG
//!   contract (bitwise-identical to the historical `encoder_forward`).
//!
//! The historical wrapper zoo (`encoder_forward_scratch`,
//! `encoder_forward_batch`, `encoder_forward_batch_pooled`) is kept as
//! thin `#[deprecated]` shims over the same cores, with bitwise-parity
//! locked in by `tests/prop_engine.rs`.

use crate::data::Rng;
use crate::error::Result;
use crate::merge::batch::{parallel_for2_mut_ctx, FragQueue};
use crate::merge::energy::layer_margin;
use crate::merge::{merge_step_scratch, MergeCtx, MergeMode, MergeScratch};
use crate::obs::merge_stats::MergeTelemetry;
use crate::obs::ring::RingWriter;
use crate::obs::stages::Stage;
use crate::tensor::{add_inplace, dense_into, dot, gelu_inplace, layernorm,
                    layernorm_into, matmul_into, softmax_rows, Mat, MatRef};

use super::params::{MatSpan, ParamStore, VecSpan};

/// Encoder hyperparameters (subset shared by ViT and text models).
#[derive(Clone, Debug, PartialEq)]
pub struct EncoderCfg {
    /// parameter-name prefix, e.g. "vit."
    pub prefix: String,
    /// embedding dim
    pub dim: usize,
    /// depth
    pub depth: usize,
    /// heads
    pub heads: usize,
    /// merge mode
    pub mode: MergeMode,
    /// static token plan (len depth+1)
    pub plan: Vec<usize>,
    /// proportional attention
    pub prop_attn: bool,
    /// ToFu prune threshold (see `config::DEFAULT_TOFU_PRUNE_THRESHOLD`)
    pub tofu_threshold: f32,
}

impl EncoderCfg {
    /// The encoder config a ViT model config implies (prefix `"vit."`).
    pub fn from_vit(cfg: &crate::config::ViTConfig) -> EncoderCfg {
        EncoderCfg {
            prefix: "vit.".into(),
            dim: cfg.dim,
            depth: cfg.depth,
            heads: cfg.heads,
            mode: cfg.mode(),
            plan: cfg.plan(),
            prop_attn: cfg.prop_attn,
            tofu_threshold: cfg.tofu_threshold,
        }
    }

    /// The encoder config a text model config implies (prefix `"bert."`).
    pub fn from_text(cfg: &crate::config::TextConfig) -> EncoderCfg {
        EncoderCfg {
            prefix: "bert.".into(),
            dim: cfg.dim,
            depth: cfg.depth,
            heads: cfg.heads,
            mode: cfg.mode(),
            plan: cfg.plan(),
            prop_attn: cfg.prop_attn,
            tofu_threshold: cfg.tofu_threshold,
        }
    }
}

/// All parameter views one block needs, resolved once per forward call so
/// the layer loop performs no name formatting and no weight copies.
struct BlockParams<'a> {
    ln1_w: &'a [f32],
    ln1_b: &'a [f32],
    wq: MatRef<'a>,
    wk: MatRef<'a>,
    wv: MatRef<'a>,
    wo: MatRef<'a>,
    bo: &'a [f32],
    ln2_w: &'a [f32],
    ln2_b: &'a [f32],
    mlp1: MatRef<'a>,
    mlp1_b: &'a [f32],
    mlp2: MatRef<'a>,
    mlp2_b: &'a [f32],
}

/// Resolved spans of every tensor one block needs.
struct BlockSpans {
    ln1_w: VecSpan,
    ln1_b: VecSpan,
    wq: MatSpan,
    wk: MatSpan,
    wv: MatSpan,
    wo: MatSpan,
    bo: VecSpan,
    ln2_w: VecSpan,
    ln2_b: VecSpan,
    mlp1: MatSpan,
    mlp1_b: VecSpan,
    mlp2: MatSpan,
    mlp2_b: VecSpan,
}

/// Encoder weights resolved to owned spans over the store's flat vector:
/// one name lookup per tensor at construction, zero lookups (and zero
/// allocations) in the layer loop, which rehydrates borrowed views per
/// block via [`ParamStore::mat_at`]/[`ParamStore::vec_at`].
///
/// Because a resolution borrows nothing, it can be cached and shared —
/// [`crate::engine::Engine`] keeps one per [`EncoderCfg`] so no consumer
/// ever re-resolves weights per batch.
pub struct ResolvedEncoder {
    blocks: Vec<BlockSpans>,
    lnf_w: VecSpan,
    lnf_b: VecSpan,
}

impl ResolvedEncoder {
    /// Resolve every tensor `cfg` names inside `ps`.
    // lint: allow(alloc) reason=one-time parameter-name resolution at engine construction
    pub fn new(ps: &ParamStore, cfg: &EncoderCfg) -> Result<ResolvedEncoder> {
        let mut blocks = Vec::with_capacity(cfg.depth);
        for l in 0..cfg.depth {
            let b = format!("{}blk{}.", cfg.prefix, l);
            blocks.push(BlockSpans {
                ln1_w: ps.vec1_span(&format!("{b}ln1.w"))?,
                ln1_b: ps.vec1_span(&format!("{b}ln1.b"))?,
                wq: ps.mat2_span(&format!("{b}wq"))?,
                wk: ps.mat2_span(&format!("{b}wk"))?,
                wv: ps.mat2_span(&format!("{b}wv"))?,
                wo: ps.mat2_span(&format!("{b}wo"))?,
                bo: ps.vec1_span(&format!("{b}bo"))?,
                ln2_w: ps.vec1_span(&format!("{b}ln2.w"))?,
                ln2_b: ps.vec1_span(&format!("{b}ln2.b"))?,
                mlp1: ps.mat2_span(&format!("{b}mlp1"))?,
                mlp1_b: ps.vec1_span(&format!("{b}mlp1b"))?,
                mlp2: ps.mat2_span(&format!("{b}mlp2"))?,
                mlp2_b: ps.vec1_span(&format!("{b}mlp2b"))?,
            });
        }
        Ok(ResolvedEncoder {
            blocks,
            lnf_w: ps.vec1_span(&format!("{}lnf.w", cfg.prefix))?,
            lnf_b: ps.vec1_span(&format!("{}lnf.b", cfg.prefix))?,
        })
    }

    /// Rehydrate block `l`'s parameter views (pure slicing, no lookup).
    #[inline]
    fn block<'a>(&self, ps: &'a ParamStore, l: usize) -> BlockParams<'a> {
        let b = &self.blocks[l];
        BlockParams {
            ln1_w: ps.vec_at(b.ln1_w),
            ln1_b: ps.vec_at(b.ln1_b),
            wq: ps.mat_at(b.wq),
            wk: ps.mat_at(b.wk),
            wv: ps.mat_at(b.wv),
            wo: ps.mat_at(b.wo),
            bo: ps.vec_at(b.bo),
            ln2_w: ps.vec_at(b.ln2_w),
            ln2_b: ps.vec_at(b.ln2_b),
            mlp1: ps.mat_at(b.mlp1),
            mlp1_b: ps.vec_at(b.mlp1_b),
            mlp2: ps.mat_at(b.mlp2),
            mlp2_b: ps.vec_at(b.mlp2_b),
        }
    }

    /// Output LayerNorm — allocates the returned matrix (it is the
    /// result handed to the caller, not a reusable buffer).  Hot callers
    /// use [`ResolvedEncoder::final_norm_into`] with a pooled buffer.
    pub fn final_norm(&self, ps: &ParamStore, x: &Mat) -> Mat {
        layernorm(x, ps.vec_at(self.lnf_w), ps.vec_at(self.lnf_b), 1e-5)
    }

    /// Output LayerNorm into a caller-owned (pooled) buffer —
    /// allocation-free once `out` has seen the shape.
    pub fn final_norm_into(&self, ps: &ParamStore, x: &Mat, out: &mut Mat) {
        layernorm_into(x, ps.vec_at(self.lnf_w), ps.vec_at(self.lnf_b), 1e-5,
                       out);
    }
}

/// Reusable buffers for the attention and MLP halves of a block.
struct BlockBufs {
    /// pre-LN output (shared by both halves)
    ln: Mat,
    /// Q projection (n, dim)
    q: Mat,
    /// K projection — doubles as the merge similarity signal
    k: Mat,
    /// V projection (n, dim)
    v: Mat,
    /// head-major packed K tile (heads·n, d): row `h·n + j` is head h's
    /// K row j, so the scoring loop streams d-contiguous rows instead of
    /// striding across the full (n, dim) K matrix
    ktile: Mat,
    /// per-head (n, n) score tile
    scores: Mat,
    /// attention output (n, dim)
    attn: Mat,
    /// output projection / MLP output (n, dim)
    proj: Mat,
    /// MLP hidden state (n, mlp_hidden)
    hidden: Mat,
    /// mean CLS attention over heads (len n)
    attn_cls: Vec<f32>,
    /// log token sizes (proportional-attention bias, len n)
    log_m: Vec<f32>,
    /// unbiased CLS logits scratch (len n)
    row0: Vec<f32>,
}

impl BlockBufs {
    // lint: allow(alloc) reason=cold constructor: scratch buffers grow on first use
    fn new() -> BlockBufs {
        BlockBufs {
            ln: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            ktile: Mat::zeros(0, 0),
            scores: Mat::zeros(0, 0),
            attn: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            hidden: Mat::zeros(0, 0),
            attn_cls: Vec::new(),
            log_m: Vec::new(),
            row0: Vec::new(),
        }
    }
}

/// Per-worker reusable workspace for the whole encoder forward (see the
/// module docs for ownership and reuse rules).  Buffers grow to the
/// largest shape they ever see and are then reused allocation-free across
/// layers, samples, and requests.
pub struct EncoderScratch {
    bufs: BlockBufs,
    merge: MergeScratch,
}

impl EncoderScratch {
    /// Empty workspace; buffers grow on first use.
    pub fn new() -> EncoderScratch {
        EncoderScratch { bufs: BlockBufs::new(), merge: MergeScratch::new() }
    }

    /// Attach (or detach) a span recorder: the layer loop then records
    /// per-layer attention/gram/plan/apply spans through it.  One live
    /// recorder per ring — attach to exactly one scratch (the primary
    /// lane); see the single-producer contract in [`crate::obs::ring`].
    pub fn set_recorder(&mut self, rec: Option<RingWriter>) {
        self.merge.recorder = rec;
    }

    /// Whether a span recorder is attached.
    pub fn has_recorder(&self) -> bool {
        self.merge.recorder.is_some()
    }

    /// Enable per-layer merge telemetry capture with room for `rows`
    /// entries (size as depth × max batch for a serving worker).
    pub fn enable_merge_telemetry(&mut self, rows: usize) {
        self.merge.telemetry.enable(rows);
    }

    /// Forget captured merge telemetry rows (start of a batch).
    pub fn reset_merge_telemetry(&mut self) {
        self.merge.telemetry.reset();
    }

    /// The merge telemetry captured since the last reset.
    pub fn merge_telemetry(&self) -> &MergeTelemetry {
        &self.merge.telemetry
    }
}

impl Default for EncoderScratch {
    fn default() -> Self {
        EncoderScratch::new()
    }
}

/// A pool of per-worker scratches for the batch driver.  Keep one alive
/// per serving worker thread so steady-state batches never reallocate
/// encoder buffers; it grows lazily to the worker count in use.
pub struct ScratchPool {
    scratches: Vec<EncoderScratch>,
    /// span recorder for the primary lane (scratch 0); parallel fan-out
    /// lanes stay uninstrumented so the ring keeps a single producer
    recorder: Option<RingWriter>,
    /// merge-telemetry capacity for the primary lane (0 = disabled)
    telemetry_rows: usize,
}

impl ScratchPool {
    /// Empty pool; scratches are created on first use and then reused.
    // lint: allow(alloc) reason=cold constructor: pool starts empty and grows on first use
    pub fn new() -> ScratchPool {
        ScratchPool { scratches: Vec::new(), recorder: None,
                      telemetry_rows: 0 }
    }

    /// Configure observability for the pool's primary lane: scratch 0
    /// gets the span recorder and a telemetry buffer of `telemetry_rows`
    /// rows; every other scratch stays silent (the ring's single-producer
    /// contract — a multi-worker fan-out samples the primary lane's
    /// layers rather than racing all lanes into one ring).
    pub fn set_observability(&mut self, rec: Option<RingWriter>,
                             telemetry_rows: usize) {
        self.recorder = rec;
        self.telemetry_rows = telemetry_rows;
        self.attach_observability();
    }

    /// (Re)attach the configured recorder/telemetry to scratch 0.
    // lint: allow(alloc) reason=cold boot/grow path: recorder Arc clone only when the pool grows or is reconfigured
    fn attach_observability(&mut self) {
        if let Some(first) = self.scratches.first_mut() {
            first.set_recorder(self.recorder.clone());
            first.enable_merge_telemetry(self.telemetry_rows);
        }
    }

    /// Hand out `workers` scratches, growing the pool on first use (the
    /// grown scratches are reused on every later call — a pool that has
    /// seen its peak worker count never allocates again).
    pub fn take(&mut self, workers: usize) -> &mut [EncoderScratch] {
        if self.scratches.len() < workers {
            while self.scratches.len() < workers {
                self.scratches.push(EncoderScratch::new());
            }
            self.attach_observability();
        }
        &mut self.scratches[..workers]
    }

    /// The configured span recorder, if any (model-level stages — embed,
    /// head — record through the same ring as the layer loop).
    pub fn recorder(&self) -> Option<&RingWriter> {
        self.recorder.as_ref()
    }

    /// The merge telemetry captured by the primary lane since its last
    /// reset (empty when observability is off or nothing ran yet).
    pub fn merge_telemetry(&self) -> Option<&MergeTelemetry> {
        self.scratches.first().map(|s| s.merge_telemetry())
    }

    /// Reset the primary lane's merge telemetry (start of a batch).
    pub fn reset_merge_telemetry(&mut self) {
        if let Some(first) = self.scratches.first_mut() {
            first.reset_merge_telemetry();
        }
    }
}

impl Default for ScratchPool {
    fn default() -> Self {
        ScratchPool::new()
    }
}

/// Multi-head proportional attention into reusable buffers.
///
/// q, kf, v: (n, dim) pre-split projections; sizes: len n.  Leaves the
/// attention output (n, dim) in `out` and the mean CLS attention over
/// heads (len n) in `attn_cls`; `ktile`, `scores`, `log_m`, and `row0`
/// are internal scratch.  K is first packed into a head-major tile
/// (`ktile` row `h·n + j` = head h's K row j), so the per-head scoring
/// loop streams d-contiguous packed rows through the [`dot`] kernel
/// instead of striding `dim`-length rows of `kf` — same values, same
/// summation order, bitwise-identical results
/// (`tests/prop_encoder.rs::ktiled_attention_matches_row_streaming_bitwise`).
/// `out += P·Vₕ` runs as contiguous d-length axpys over the head slice —
/// the vectorized replacement for the seed's scalar triple loop (benched
/// in `benches/encoder_bench.rs`).
#[allow(clippy::too_many_arguments)]
pub fn attention_into(q: &Mat, kf: &Mat, v: &Mat, sizes: &[f32], heads: usize,
                      prop_attn: bool, ktile: &mut Mat, scores: &mut Mat,
                      out: &mut Mat, attn_cls: &mut Vec<f32>,
                      log_m: &mut Vec<f32>, row0: &mut Vec<f32>) {
    let n = q.rows;
    let dim = q.cols;
    let d = dim / heads;
    let scale = 1.0 / (d as f32).sqrt();
    debug_assert_eq!(sizes.len(), n);
    log_m.clear();
    if prop_attn {
        log_m.extend(sizes.iter().map(|&s| s.max(1e-9).ln()));
    } else {
        log_m.resize(n, 0.0);
    }
    out.reset(n, dim);
    attn_cls.clear();
    attn_cls.resize(n, 0.0);
    row0.clear();
    row0.resize(n, 0.0);
    // pack K head-major once per block: row h·n + j holds head h's K row
    // j as a dense d-length slice, so every head's scoring pass below
    // reads a compact (n, d) tile instead of touching d useful floats
    // out of every dim-length row of `kf`
    ktile.reshape(heads * n, d);
    for j in 0..n {
        let kr = kf.row(j);
        for hh in 0..heads {
            ktile.row_mut(hh * n + j)
                .copy_from_slice(&kr[hh * d..(hh + 1) * d]);
        }
    }
    for hh in 0..heads {
        let col0 = hh * d;
        let h0 = hh * n;
        // scores = qh @ kh^T * scale + log m
        scores.reshape(n, n);
        for i in 0..n {
            let qi = &q.row(i)[col0..col0 + d];
            let srow = scores.row_mut(i);
            for (j, sj) in srow.iter_mut().enumerate() {
                let kj = ktile.row(h0 + j);
                *sj = dot(qi, kj) * scale + log_m[j];
            }
        }
        // CLS attention uses the *unbiased* logits, matching model.py
        {
            let s0 = scores.row(0);
            for (r0, (sv, lm)) in row0.iter_mut().zip(s0.iter().zip(log_m.iter())) {
                *r0 = *sv - *lm;
            }
            let mx = row0.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for vj in row0.iter_mut() {
                *vj = (*vj - mx).exp();
                sum += *vj;
            }
            for (a, vj) in attn_cls.iter_mut().zip(row0.iter()) {
                *a += vj / sum / heads as f32;
            }
        }
        softmax_rows(scores);
        // out_h += P @ V_h
        for i in 0..n {
            let orow = &mut out.row_mut(i)[col0..col0 + d];
            let prow = scores.row(i);
            for (j, &p) in prow.iter().enumerate() {
                if p == 0.0 {
                    continue;
                }
                let vj = &v.row(j)[col0..col0 + d];
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += p * vv;
                }
            }
        }
    }
}

/// Multi-head proportional attention for one sample (allocating wrapper
/// over [`attention_into`]).
///
/// q, kf, v: (n, dim) pre-split projections; sizes: len n.
/// Returns (attn output (n, dim), mean CLS attention over heads (n,)).
// lint: allow(alloc) reason=allocating convenience wrapper over attention_into
pub fn attention(q: &Mat, kf: &Mat, v: &Mat, sizes: &[f32], heads: usize,
                 prop_attn: bool) -> (Mat, Vec<f32>) {
    let mut ktile = Mat::zeros(0, 0);
    let mut scores = Mat::zeros(0, 0);
    let mut out = Mat::zeros(0, 0);
    let mut attn_cls = Vec::new();
    let mut log_m = Vec::new();
    let mut row0 = Vec::new();
    attention_into(q, kf, v, sizes, heads, prop_attn, &mut ktile, &mut scores,
                   &mut out, &mut attn_cls, &mut log_m, &mut row0);
    (out, attn_cls)
}

/// Attention half of a block: pre-LN, QKV, proportional attention, output
/// projection, residual add (in place).  Leaves the key features (the
/// merge similarity signal) in `b.k` and the mean CLS attention in
/// `b.attn_cls`.
fn block_attention_into(bp: &BlockParams, heads: usize, prop_attn: bool,
                        x: &mut Mat, sizes: &[f32], b: &mut BlockBufs) {
    layernorm_into(x, bp.ln1_w, bp.ln1_b, 1e-5, &mut b.ln);
    matmul_into(&b.ln, bp.wq, &mut b.q);
    matmul_into(&b.ln, bp.wk, &mut b.k);
    matmul_into(&b.ln, bp.wv, &mut b.v);
    attention_into(&b.q, &b.k, &b.v, sizes, heads, prop_attn, &mut b.ktile,
                   &mut b.scores, &mut b.attn, &mut b.attn_cls, &mut b.log_m,
                   &mut b.row0);
    dense_into(&b.attn, bp.wo, Some(bp.bo), &mut b.proj);
    add_inplace(x, &b.proj);
}

/// MLP half of a block: pre-LN, GELU MLP, residual add (in place).
fn block_mlp_into(bp: &BlockParams, x: &mut Mat, b: &mut BlockBufs) {
    layernorm_into(x, bp.ln2_w, bp.ln2_b, 1e-5, &mut b.ln);
    dense_into(&b.ln, bp.mlp1, Some(bp.mlp1_b), &mut b.hidden);
    gelu_inplace(&mut b.hidden);
    dense_into(&b.hidden, bp.mlp2, Some(bp.mlp2_b), &mut b.proj);
    add_inplace(x, &b.proj);
}

/// Where a merge layer's RNG comes from.
enum LayerRng<'r> {
    /// one caller-owned stream across all layers (the serial contract)
    Shared(&'r mut Rng),
    /// a fresh `Rng::new(seed ^ (l << 32) ^ sample)` per layer (the batch
    /// contract — reproducible under any thread schedule)
    PerLayer {
        /// batch seed
        seed: u64,
        /// sample index within the batch
        sample: u64,
    },
}

/// The encoder layer loop over pre-resolved weights: attention, merge
/// (Eq. 2), MLP per layer, all in place through the scratch.
fn run_layers(ps: &ParamStore, re: &ResolvedEncoder, cfg: &EncoderCfg,
              x: &mut Mat, sizes: &mut Vec<f32>, mut lr: LayerRng,
              s: &mut EncoderScratch) {
    for l in 0..cfg.depth {
        let n_in = cfg.plan[l];
        let n_out = cfg.plan[l + 1];
        debug_assert_eq!(x.rows, n_in, "plan mismatch at layer {l}");
        let bp = re.block(ps, l);

        let t0 = s.merge.recorder.as_ref().map(|r| r.now_us());
        block_attention_into(&bp, cfg.heads, cfg.prop_attn, x, &sizes[..],
                             &mut s.bufs);
        if let Some(r) = s.merge.recorder.as_ref() {
            r.span_since(Stage::LayerAttention, l as u64, t0.unwrap_or(0),
                         n_in as u32);
        }

        // merge between attention and MLP (Eq. 2)
        let k = n_in - n_out;
        if k > 0 {
            s.merge.telemetry.set_layer(l as u32);
            let margin = layer_margin(l, cfg.depth);
            let ctx = MergeCtx {
                x: &*x,
                kf: &s.bufs.k,
                sizes: &sizes[..],
                attn_cls: &s.bufs.attn_cls,
                margin,
                k,
                protect_first: 1,
                tofu_threshold: cfg.tofu_threshold,
            };
            match &mut lr {
                LayerRng::Shared(rng) => {
                    merge_step_scratch(cfg.mode, &ctx, rng, &mut s.merge);
                }
                LayerRng::PerLayer { seed, sample } => {
                    let mut rng =
                        Rng::new(*seed ^ ((l as u64) << 32) ^ *sample);
                    merge_step_scratch(cfg.mode, &ctx, &mut rng, &mut s.merge);
                }
            }
            // ping-pong: the merged tokens become the live state and the
            // old state becomes next step's output buffer
            std::mem::swap(x, &mut s.merge.out_x);
            std::mem::swap(sizes, &mut s.merge.out_sizes);
        }

        block_mlp_into(&bp, x, &mut s.bufs);
    }
}

/// Run the encoder layer stack in place over pre-resolved weights — the
/// zero-allocation steady-state core (`x` and `sizes` are updated in
/// place; apply [`ResolvedEncoder::final_norm_into`] afterwards for the
/// full forward).  With a warmed scratch this performs no heap
/// allocations in any merge mode.  Exposed so benches and the
/// alloc-counter tests can measure exactly the layer loop.
pub fn encoder_layers(ps: &ParamStore, re: &ResolvedEncoder,
                      cfg: &EncoderCfg, x: &mut Mat, sizes: &mut Vec<f32>,
                      rng: &mut Rng, scratch: &mut EncoderScratch) {
    run_layers(ps, re, cfg, x, sizes, LayerRng::Shared(rng), scratch);
}

/// One sequence's state in the slot-based batch driver: the live token
/// matrix (consumed in place by the layer loop) and its size vector.
/// Slots are pooled by [`crate::engine::Session`] so a steady-state
/// server refills them without allocating.
pub struct SeqSlot {
    /// token matrix; the layer loop shrinks it in place
    pub x: Mat,
    /// per-token merged-cardinality sizes (reset to 1.0 by `set_input`)
    pub sizes: Vec<f32>,
}

impl SeqSlot {
    /// Empty slot; buffers grow on first use.
    // lint: allow(alloc) reason=cold constructor: slot buffers grow on first use
    pub fn new() -> SeqSlot {
        SeqSlot { x: Mat::zeros(0, 0), sizes: Vec::new() }
    }

    /// Load an input sample: copy `x` in and reset sizes to 1.0
    /// (allocation-free once the slot has seen the shape).
    pub fn set_input(&mut self, x: &Mat) {
        self.x.copy_from(x);
        self.reset_sizes();
    }

    /// Reset the size vector to 1.0 per current token (callers that fill
    /// `x` directly — e.g. embedding kernels — use this instead of
    /// [`SeqSlot::set_input`]).
    pub fn reset_sizes(&mut self) {
        self.sizes.clear();
        self.sizes.resize(self.x.rows, 1f32);
    }
}

impl Default for SeqSlot {
    fn default() -> Self {
        SeqSlot::new()
    }
}

/// Run the encoder over a batch of pre-filled slots, writing each final
/// (normed) token matrix into the matching `outs` buffer — the shared
/// zero-allocation batch core behind both [`crate::engine::Session`] and
/// the legacy wrappers.
///
/// Samples fan out over `scratches.len()` scoped worker threads (1 =
/// inline, no spawns), each worker reusing one scratch for every sample
/// it processes.  `seed` derives one deterministic RNG seed per (layer,
/// sample), so stochastic modes are reproducible under any thread
/// schedule.  With warmed slots/outputs/scratches and one worker, the
/// whole call performs zero heap allocations (`tests/alloc_free.rs`).
pub fn encoder_forward_slots(ps: &ParamStore, re: &ResolvedEncoder,
                             cfg: &EncoderCfg, slots: &mut [SeqSlot],
                             outs: &mut [Mat], seed: u64,
                             scratches: &mut [EncoderScratch]) {
    debug_assert_eq!(slots.len(), outs.len());
    parallel_for2_mut_ctx(
        slots,
        outs,
        scratches,
        &|i, slot: &mut SeqSlot, out: &mut Mat, scratch: &mut EncoderScratch| {
            run_layers(ps, re, cfg, &mut slot.x, &mut slot.sizes,
                       LayerRng::PerLayer { seed, sample: i as u64 }, scratch);
            re.final_norm_into(ps, &slot.x, out);
        },
    );
}

/// Run the encoder on one pre-filled slot with the serial shared-RNG
/// contract (the single-sample counterpart of [`encoder_forward_slots`];
/// bitwise-identical to the historical [`encoder_forward`] for every
/// mode, stochastic ones included, because it consumes the same caller
/// RNG stream).
pub fn encoder_forward_slot(ps: &ParamStore, re: &ResolvedEncoder,
                            cfg: &EncoderCfg, slot: &mut SeqSlot,
                            out: &mut Mat, rng: &mut Rng,
                            scratch: &mut EncoderScratch) {
    run_layers(ps, re, cfg, &mut slot.x, &mut slot.sizes,
               LayerRng::Shared(rng), scratch);
    re.final_norm_into(ps, &slot.x, out);
}

/// Run the encoder on one sample `x` (plan[0], dim). Returns final tokens
/// (plan[depth], dim) after the output LayerNorm.  One-shot entry point
/// (and the python-parity contract); hot callers hold a
/// [`crate::engine::Session`] instead.
// lint: allow(alloc) reason=one-shot parity entry point; hot callers hold a Session
pub fn encoder_forward(ps: &ParamStore, cfg: &EncoderCfg, x: Mat,
                       rng: &mut Rng) -> Result<Mat> {
    let re = ResolvedEncoder::new(ps, cfg)?;
    let mut slot = SeqSlot { sizes: vec![1f32; x.rows], x };
    let mut out = Mat::zeros(0, 0);
    let mut scratch = EncoderScratch::new();
    encoder_forward_slot(ps, &re, cfg, &mut slot, &mut out, rng, &mut scratch);
    Ok(out)
}

/// Run the encoder on one sample `x` with a caller-owned scratch.
// lint: allow(alloc) reason=deprecated one-shot wrapper retained for parity tests
#[deprecated(note = "hold a `crate::engine::Session` and use \
                     `Session::forward_one` instead")]
pub fn encoder_forward_scratch(ps: &ParamStore, cfg: &EncoderCfg, x: Mat,
                               rng: &mut Rng, scratch: &mut EncoderScratch)
                               -> Result<Mat> {
    let re = ResolvedEncoder::new(ps, cfg)?;
    let mut slot = SeqSlot { sizes: vec![1f32; x.rows], x };
    let mut out = Mat::zeros(0, 0);
    encoder_forward_slot(ps, &re, cfg, &mut slot, &mut out, rng, scratch);
    Ok(out)
}

/// Run the encoder on a batch of samples with a caller-owned scratch
/// pool (per-sample outputs are still allocated; the engine API pools
// lint: allow(alloc) reason=deprecated batch wrapper retained for compatibility
/// them too).
#[deprecated(note = "use `crate::engine::Engine::session` → \
                     `Session::forward_batch` instead")]
pub fn encoder_forward_batch_pooled(ps: &ParamStore, cfg: &EncoderCfg,
                                    xs: Vec<Mat>, seed: u64, workers: usize,
                                    pool: &mut ScratchPool)
                                    -> Result<Vec<Mat>> {
    let re = ResolvedEncoder::new(ps, cfg)?;
    let mut slots: Vec<SeqSlot> = xs
        .into_iter()
        .map(|x| SeqSlot { sizes: vec![1f32; x.rows], x })
        .collect();
    if slots.is_empty() {
        return Ok(Vec::new());
    }
    let mut outs: Vec<Mat> = (0..slots.len()).map(|_| Mat::zeros(0, 0)).collect();
    let w = workers.max(1).min(slots.len());
    encoder_forward_slots(ps, &re, cfg, &mut slots, &mut outs, seed,
                          pool.take(w));
    Ok(outs)
}

/// Run the encoder on a batch of samples with a transient scratch pool.
#[deprecated(note = "use `crate::engine::Engine::session` → \
                     `Session::forward_batch` instead")]
pub fn encoder_forward_batch(ps: &ParamStore, cfg: &EncoderCfg, xs: Vec<Mat>,
                             seed: u64, workers: usize) -> Result<Vec<Mat>> {
    let mut pool = ScratchPool::new();
    encoder_forward_batch_pooled(ps, cfg, xs, seed, workers, &mut pool)
}

/// One tower's pre-filled batch for [`encoder_forward_towers`]: the
/// resolved weights and config, the input slots, the matching output
/// buffers, and the tower's batch seed (per-(layer, sample) RNG
/// derivation, so results are identical under any worker schedule).
pub struct TowerBatch<'a> {
    /// resolved weights of this tower
    pub re: &'a ResolvedEncoder,
    /// this tower's encoder config
    pub cfg: &'a EncoderCfg,
    /// pre-filled input slots (consumed in place by the layer loop)
    pub slots: &'a mut [SeqSlot],
    /// per-sample output buffers (same length as `slots`)
    pub outs: &'a mut [Mat],
    /// batch seed for this tower
    pub seed: u64,
}

/// A tower's fragment queue plus the context workers need to drain it.
struct TowerQueue<'a> {
    frags: FragQueue<'a, SeqSlot, Mat>,
    re: &'a ResolvedEncoder,
    cfg: &'a EncoderCfg,
    seed: u64,
}

/// Drain one tower serially in slot order — the exact per-sample
/// computation of [`encoder_forward_slots`] (per-(layer, sample) seeds),
/// shared by the inline path and the stealing workers.
fn run_tower_serial(ps: &ParamStore, tb: TowerBatch<'_>,
                    scratch: &mut EncoderScratch) {
    for (i, (slot, out)) in
        tb.slots.iter_mut().zip(tb.outs.iter_mut()).enumerate()
    {
        run_layers(ps, tb.re, tb.cfg, &mut slot.x, &mut slot.sizes,
                   LayerRng::PerLayer { seed: tb.seed, sample: i as u64 },
                   scratch);
        tb.re.final_norm_into(ps, &slot.x, out);
    }
}

/// One stealing worker: drain the preferred tower's queue, stealing
/// fragments from the other tower whenever the preferred one runs dry,
/// until both are empty.  Each queue's internal mutex is a leaf lock
/// held only for the O(1) fragment split — never across the layer loop
/// and never while touching the other queue — so workers cannot
/// deadlock or serialize on each other.
fn drain_towers(ps: &ParamStore, queues: [&TowerQueue<'_>; 2], prefer: usize,
                scratch: &mut EncoderScratch) {
    loop {
        let mut next = None;
        for qi in [prefer, 1 - prefer] {
            if let Some(frag) = queues[qi].frags.pop() {
                next = Some((qi, frag));
                break;
            }
        }
        let Some((qi, (base, slots, outs))) = next else { return };
        let q = queues[qi];
        for (off, (slot, out)) in
            slots.iter_mut().zip(outs.iter_mut()).enumerate()
        {
            run_layers(ps, q.re, q.cfg, &mut slot.x, &mut slot.sizes,
                       LayerRng::PerLayer { seed: q.seed,
                                            sample: (base + off) as u64 },
                       scratch);
            q.re.final_norm_into(ps, &slot.x, out);
        }
    }
}

/// Run two towers' batches (e.g. a joint request's vision and text
/// halves) over one pool of stealing workers: each tower's slots are
/// split into batch fragments behind a [`FragQueue`], `scratches.len()`
/// workers drain them — each preferring one tower but stealing from the
/// other when idle — so one slow or oversized tower half can no longer
/// idle the rest of the pool (ROADMAP item 5).
///
/// Per-(layer, sample) RNG seeding makes the result **bitwise identical**
/// to running [`encoder_forward_slots`] per tower at any worker count,
/// no matter which worker steals which fragment
/// (`engine::multimodal` tests assert this across worker counts).
/// With one scratch the towers run inline, serially, with zero spawns —
/// the allocation-free serving configuration.
pub fn encoder_forward_towers(ps: &ParamStore, vis: TowerBatch<'_>,
                              txt: TowerBatch<'_>,
                              scratches: &mut [EncoderScratch]) {
    debug_assert_eq!(vis.slots.len(), vis.outs.len());
    debug_assert_eq!(txt.slots.len(), txt.outs.len());
    let total = vis.slots.len() + txt.slots.len();
    let workers = scratches.len().min(total).max(1);
    if workers <= 1 {
        let scratch = &mut scratches[0];
        run_tower_serial(ps, vis, scratch);
        run_tower_serial(ps, txt, scratch);
        return;
    }
    // fragments sized for ~2 per worker across both towers, so stealing
    // has slack without shredding cache locality
    let frag = (total / (workers * 2)).max(1);
    let vq = TowerQueue {
        frags: FragQueue::new(vis.slots, vis.outs, frag),
        re: vis.re,
        cfg: vis.cfg,
        seed: vis.seed,
    };
    let tq = TowerQueue {
        frags: FragQueue::new(txt.slots, txt.outs, frag),
        re: txt.re,
        cfg: txt.cfg,
        seed: txt.seed,
    };
    let queues = [&vq, &tq];
    let (first, rest) = scratches.split_first_mut().expect("workers >= 1");
    std::thread::scope(|scope| {
        for (w, scratch) in rest.iter_mut().enumerate().take(workers - 1) {
            scope.spawn(move || {
                drain_towers(ps, queues, (w + 1) % 2, scratch);
            });
        }
        // the calling thread is worker 0 and prefers the vision tower
        drain_towers(ps, queues, 0, first);
    });
}

/// Plain (non-proportional) attention convenience used in tests.
// lint: allow(alloc) reason=reference implementation used by parity tests only
pub fn plain_attention(q: &Mat, kf: &Mat, v: &Mat, heads: usize) -> Mat {
    let ones = vec![1.0; q.rows];
    attention(q, kf, v, &ones, heads, true).0
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // legacy wrappers stay parity-tested here

    use super::*;
    use crate::config::ViTConfig;
    use crate::model::params::synthetic_vit_store;

    #[test]
    fn attention_rows_are_convex_combinations() {
        let mut rng = Rng::new(2);
        let n = 7;
        let q = Mat::from_fn(n, 8, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let kf = Mat::from_fn(n, 8, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let v = Mat::from_fn(n, 8, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let (o, attn_cls) = attention(&q, &kf, &v, &vec![1.0; n], 2, true);
        assert_eq!(o.rows, n);
        let s: f32 = attn_cls.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "cls attn sums to {s}");
        // each output coordinate within v's column bounds per head block
        for c in 0..8 {
            let cmax = (0..n).map(|i| v.get(i, c)).fold(f32::MIN, f32::max);
            let cmin = (0..n).map(|i| v.get(i, c)).fold(f32::MAX, f32::min);
            for i in 0..n {
                assert!(o.get(i, c) <= cmax + 1e-5);
                assert!(o.get(i, c) >= cmin - 1e-5);
            }
        }
    }

    #[test]
    fn size_bias_shifts_attention() {
        let n = 5;
        let q = Mat::from_fn(n, 4, |_, _| 1.0);
        let kf = Mat::zeros(n, 4); // uniform logits
        let v = Mat::from_fn(n, 4, |i, j| if i == 3 && j == 0 { 10.0 } else { 0.0 });
        let mut sizes = vec![1.0; n];
        sizes[3] = 1e6;
        let (o, _) = attention(&q, &kf, &v, &sizes, 1, true);
        assert!(o.get(0, 0) > 9.0, "huge token dominates: {}", o.get(0, 0));
    }

    #[test]
    fn attention_into_reused_buffers_match_fresh() {
        let mut rng = Rng::new(5);
        let mut ktile = Mat::zeros(0, 0);
        let mut scores = Mat::zeros(0, 0);
        let mut out = Mat::zeros(0, 0);
        let mut attn_cls = Vec::new();
        let mut log_m = Vec::new();
        let mut row0 = Vec::new();
        // descending n: the reused buffers shrink logically between calls
        for (n, dim, heads) in [(16usize, 16usize, 4usize), (9, 8, 2), (5, 8, 1)] {
            let q = Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
            let kf = Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
            let v = Mat::from_fn(n, dim, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
            let sizes: Vec<f32> = (0..n).map(|i| 1.0 + (i % 3) as f32).collect();
            for prop in [true, false] {
                let (want, want_cls) = attention(&q, &kf, &v, &sizes, heads, prop);
                attention_into(&q, &kf, &v, &sizes, heads, prop, &mut ktile,
                               &mut scores, &mut out, &mut attn_cls,
                               &mut log_m, &mut row0);
                assert_eq!(out.rows, want.rows);
                assert!(out.max_abs_diff(&want) == 0.0, "n={n} prop={prop}");
                assert_eq!(attn_cls, want_cls, "n={n} prop={prop}");
            }
        }
    }

    fn test_cfg(mode: &str) -> (ViTConfig, EncoderCfg) {
        let vcfg = ViTConfig {
            merge_mode: mode.into(),
            merge_r: 0.9,
            ..Default::default()
        };
        let cfg = EncoderCfg {
            prefix: "vit.".into(),
            dim: vcfg.dim,
            depth: vcfg.depth,
            heads: vcfg.heads,
            mode: vcfg.mode(),
            plan: vcfg.plan(),
            prop_attn: true,
            tofu_threshold: vcfg.tofu_threshold,
        };
        (vcfg, cfg)
    }

    #[test]
    fn scratch_forward_matches_wrapper_forward() {
        let (vcfg, cfg) = test_cfg("pitome");
        let ps = synthetic_vit_store(&vcfg, 42);
        let n0 = cfg.plan[0];
        let mut rng = Rng::new(9);
        let mut scratch = EncoderScratch::new();
        for trial in 0..3 {
            let x = Mat::from_fn(n0, cfg.dim,
                                 |_, _| (rng.next_f64() * 0.2 - 0.1) as f32);
            let mut r1 = Rng::new(trial);
            let want = encoder_forward(&ps, &cfg, x.clone(), &mut r1).unwrap();
            let mut r2 = Rng::new(trial);
            let got = encoder_forward_scratch(&ps, &cfg, x, &mut r2,
                                              &mut scratch).unwrap();
            assert_eq!(got.rows, want.rows);
            assert!(got.max_abs_diff(&want) == 0.0, "trial {trial}");
        }
    }

    #[test]
    fn batch_forward_matches_serial_forward() {
        let (vcfg, cfg) = test_cfg("pitome");
        let ps = synthetic_vit_store(&vcfg, 42);
        let n0 = cfg.plan[0];
        let mut rng = Rng::new(9);
        let xs: Vec<Mat> = (0..5)
            .map(|_| Mat::from_fn(n0, cfg.dim,
                                  |_, _| (rng.next_f64() * 0.2 - 0.1) as f32))
            .collect();
        // shared-scratch batch driver: the same pool serves two rounds, so
        // round 2 runs entirely on reused buffers
        let mut pool = ScratchPool::new();
        for round in 0..2 {
            let batched = encoder_forward_batch_pooled(
                &ps, &cfg, xs.clone(), 0, 3, &mut pool).unwrap();
            for (i, x) in xs.iter().enumerate() {
                let mut r = Rng::new(0);
                let want = encoder_forward(&ps, &cfg, x.clone(), &mut r).unwrap();
                assert_eq!(batched[i].rows, want.rows);
                assert!(batched[i].max_abs_diff(&want) < 1e-5,
                        "round {round} sample {i} diverged: {}",
                        batched[i].max_abs_diff(&want));
            }
        }
        // the transient-pool wrapper agrees too
        let wrapper = encoder_forward_batch(&ps, &cfg, xs.clone(), 0, 3).unwrap();
        let pooled = encoder_forward_batch_pooled(&ps, &cfg, xs, 0, 3,
                                                  &mut pool).unwrap();
        for (a, b) in wrapper.iter().zip(&pooled) {
            assert!(a.max_abs_diff(b) == 0.0);
        }
    }

    /// A forward with a recorder + telemetry attached is bitwise
    /// identical to an unobserved one, records one attention span per
    /// layer, and captures one telemetry row per merging layer with the
    /// plan's token counts.
    #[test]
    fn instrumented_forward_matches_and_reports_layers() {
        let (vcfg, cfg) = test_cfg("pitome");
        let ps = synthetic_vit_store(&vcfg, 42);
        let re = ResolvedEncoder::new(&ps, &cfg).unwrap();
        let n0 = cfg.plan[0];
        let mut rng = Rng::new(9);
        let x = Mat::from_fn(n0, cfg.dim,
                             |_, _| (rng.next_f64() * 0.2 - 0.1) as f32);

        let mut bare = EncoderScratch::new();
        let mut slot = SeqSlot::new();
        slot.set_input(&x);
        let mut want = Mat::zeros(0, 0);
        let mut r1 = Rng::new(1);
        encoder_forward_slot(&ps, &re, &cfg, &mut slot, &mut want, &mut r1,
                             &mut bare);

        let ring = crate::obs::SpanRing::with_capacity(256);
        let mut obs = EncoderScratch::new();
        obs.set_recorder(Some(ring.writer(std::time::Instant::now())));
        obs.enable_merge_telemetry(cfg.depth);
        let mut slot2 = SeqSlot::new();
        slot2.set_input(&x);
        let mut got = Mat::zeros(0, 0);
        let mut r2 = Rng::new(1);
        encoder_forward_slot(&ps, &re, &cfg, &mut slot2, &mut got, &mut r2,
                             &mut obs);
        assert!(got.max_abs_diff(&want) == 0.0,
                "observation must not change the forward");

        let merging_layers: Vec<usize> = (0..cfg.depth)
            .filter(|&l| cfg.plan[l] > cfg.plan[l + 1])
            .collect();
        let rows = obs.merge_telemetry().rows();
        assert_eq!(rows.len(), merging_layers.len());
        for (row, &l) in rows.iter().zip(&merging_layers) {
            assert_eq!(row.layer as usize, l);
            assert_eq!(row.tokens_before as usize, cfg.plan[l]);
            assert_eq!(row.tokens_after as usize, cfg.plan[l + 1]);
        }
        let mut events = Vec::new();
        ring.drain_into(&mut events);
        let attn = events.iter()
            .filter(|e| e.stage == Stage::LayerAttention).count();
        assert_eq!(attn, cfg.depth, "one attention span per layer");
        let applies = events.iter()
            .filter(|e| e.stage == Stage::LayerApply).count();
        assert_eq!(applies, merging_layers.len());
    }

    #[test]
    fn batch_forward_is_deterministic_across_worker_counts() {
        // stochastic mode: per-(layer, sample) seeds must make the result
        // independent of the fan-out
        let (vcfg, cfg) = test_cfg("pitome_rand");
        let ps = synthetic_vit_store(&vcfg, 7);
        let n0 = cfg.plan[0];
        let mut rng = Rng::new(3);
        let xs: Vec<Mat> = (0..4)
            .map(|_| Mat::from_fn(n0, cfg.dim,
                                  |_, _| (rng.next_f64() * 0.2 - 0.1) as f32))
            .collect();
        let a = encoder_forward_batch(&ps, &cfg, xs.clone(), 11, 1).unwrap();
        let b = encoder_forward_batch(&ps, &cfg, xs, 11, 4).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.max_abs_diff(y) == 0.0);
        }
    }
}
