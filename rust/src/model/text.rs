//! CPU reference text encoders: BERT-style classifier, CLIP text tower, and
//! the VQA head — all sharing [`encoder_forward`].

use crate::config::TextConfig;
use crate::data::Rng;
use crate::error::Result;
use crate::merge::MergeMode;
use crate::tensor::{dense, Mat};

use super::encoder::{encoder_forward, EncoderCfg};
use super::params::ParamStore;

/// Token embedding + position for a prefix (e.g. "bert.", "txt.", "q.").
pub fn embed_tokens(ps: &ParamStore, prefix: &str, tokens: &[i32],
                    dim: usize) -> Result<Mat> {
    let table = ps.mat2(&format!("{prefix}tok"))?;
    let pos = ps.mat2(&format!("{prefix}pos"))?;
    let n = tokens.len();
    let mut x = Mat::zeros(n, dim);
    for (i, &t) in tokens.iter().enumerate() {
        let r = x.row_mut(i);
        let e = table.row(t as usize);
        let p = pos.row(i);
        for j in 0..dim {
            r[j] = e[j] + p[j];
        }
    }
    Ok(x)
}

/// CLS feature from a text encoder with the given plan/mode.
#[allow(clippy::too_many_arguments)]
pub fn text_features(ps: &ParamStore, prefix: &str, tokens: &[i32],
                     dim: usize, depth: usize, heads: usize,
                     mode: MergeMode, plan: Vec<usize>, rng: &mut Rng)
                     -> Result<Vec<f32>> {
    let x = embed_tokens(ps, prefix, tokens, dim)?;
    let cfg = EncoderCfg {
        prefix: prefix.into(),
        dim,
        depth,
        heads,
        mode,
        plan,
        prop_attn: true,
    };
    let out = encoder_forward(ps, &cfg, x, rng)?;
    Ok(out.row(0).to_vec())
}

/// BERT-style classifier logits for one sample.
pub fn bert_logits(ps: &ParamStore, cfg: &TextConfig, tokens: &[i32],
                   rng: &mut Rng) -> Result<Vec<f32>> {
    let f = text_features(ps, "bert.", tokens, cfg.dim, cfg.depth, cfg.heads,
                          cfg.mode(), cfg.plan(), rng)?;
    let fm = Mat::from_vec(1, f.len(), f);
    let lg = dense(&fm, &ps.mat2("bert.head.w")?,
                   Some(ps.vec1("bert.head.b")?));
    Ok(lg.data)
}

/// L2-normalize a feature vector in place.
pub fn l2_normalize(v: &mut [f32]) {
    let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt() + 1e-6;
    for x in v.iter_mut() {
        *x /= n;
    }
}

/// CLIP text embedding for one caption.
pub fn clip_text_embed(ps: &ParamStore, tokens: &[i32], dim: usize,
                       depth: usize, heads: usize, embed_dim: usize,
                       rng: &mut Rng) -> Result<Vec<f32>> {
    let plan = vec![tokens.len(); depth + 1];
    let f = text_features(ps, "txt.", tokens, dim, depth, heads,
                          MergeMode::None, plan, rng)?;
    let fm = Mat::from_vec(1, f.len(), f);
    let mut e = dense(&fm, &ps.mat2("proj.txt")?, None).data;
    debug_assert_eq!(e.len(), embed_dim);
    l2_normalize(&mut e);
    Ok(e)
}
