//! CPU reference text encoders: BERT-style classifier, CLIP text tower, and
//! the VQA head — all sharing [`encoder_forward`].

use crate::config::{TextConfig, DEFAULT_TOFU_PRUNE_THRESHOLD};
use crate::data::Rng;
use crate::error::Result;
use crate::merge::MergeMode;
use crate::tensor::{dense, Mat};

#[allow(deprecated)]
use super::encoder::encoder_forward_batch_pooled;
use super::encoder::{encoder_forward, EncoderCfg, ScratchPool};
use super::params::ParamStore;

/// Token embedding + position for a prefix (e.g. "bert.", "txt.", "q.").
pub fn embed_tokens(ps: &ParamStore, prefix: &str, tokens: &[i32],
                    dim: usize) -> Result<Mat> {
    let table = ps.mat2(&format!("{prefix}tok"))?;
    let pos = ps.mat2(&format!("{prefix}pos"))?;
    let n = tokens.len();
    let mut x = Mat::zeros(n, dim);
    for (i, &t) in tokens.iter().enumerate() {
        let r = x.row_mut(i);
        let e = table.row(t as usize);
        let p = pos.row(i);
        for j in 0..dim {
            r[j] = e[j] + p[j];
        }
    }
    Ok(x)
}

fn text_encoder_cfg(prefix: &str, dim: usize, depth: usize, heads: usize,
                    mode: MergeMode, plan: Vec<usize>, tofu_threshold: f32)
                    -> EncoderCfg {
    EncoderCfg {
        prefix: prefix.into(),
        dim,
        depth,
        heads,
        mode,
        plan,
        prop_attn: true,
        tofu_threshold,
    }
}

/// CLS feature from a text encoder with the given plan/mode.  ToFu runs at
/// the config default prune threshold; use [`bert_logits`] (which reads
/// `TextConfig::tofu_threshold`) to sweep it.
#[allow(clippy::too_many_arguments)]
pub fn text_features(ps: &ParamStore, prefix: &str, tokens: &[i32],
                     dim: usize, depth: usize, heads: usize,
                     mode: MergeMode, plan: Vec<usize>, rng: &mut Rng)
                     -> Result<Vec<f32>> {
    let x = embed_tokens(ps, prefix, tokens, dim)?;
    let cfg = text_encoder_cfg(prefix, dim, depth, heads, mode, plan,
                               DEFAULT_TOFU_PRUNE_THRESHOLD);
    let out = encoder_forward(ps, &cfg, x, rng)?;
    Ok(out.row(0).to_vec())
}

fn bert_encoder_cfg(cfg: &TextConfig) -> EncoderCfg {
    EncoderCfg::from_text(cfg)
}

fn bert_head(ps: &ParamStore, f: Vec<f32>) -> Result<Vec<f32>> {
    let fm = Mat::from_vec(1, f.len(), f);
    let lg = dense(&fm, &ps.mat2("bert.head.w")?,
                   Some(ps.vec1("bert.head.b")?));
    Ok(lg.data)
}

/// BERT-style classifier logits for one sample.
pub fn bert_logits(ps: &ParamStore, cfg: &TextConfig, tokens: &[i32],
                   rng: &mut Rng) -> Result<Vec<f32>> {
    let x = embed_tokens(ps, "bert.", tokens, cfg.dim)?;
    let out = encoder_forward(ps, &bert_encoder_cfg(cfg), x, rng)?;
    bert_head(ps, out.row(0).to_vec())
}

/// BERT-style classifier logits for a batch of samples with a
/// caller-owned scratch pool: sequences fan out over `workers` threads,
/// each worker reusing one `EncoderScratch` from `pool`.
#[deprecated(note = "hold a `crate::engine::BertSession` (one per worker) \
                     instead")]
#[allow(deprecated)]
pub fn bert_logits_batch_pooled(ps: &ParamStore, cfg: &TextConfig,
                                token_seqs: &[Vec<i32>], seed: u64,
                                workers: usize, pool: &mut ScratchPool)
                                -> Result<Vec<Vec<f32>>> {
    let xs: Vec<Mat> = token_seqs
        .iter()
        .map(|t| embed_tokens(ps, "bert.", t, cfg.dim))
        .collect::<Result<_>>()?;
    let outs = encoder_forward_batch_pooled(ps, &bert_encoder_cfg(cfg), xs,
                                            seed, workers, pool)?;
    outs.into_iter()
        .map(|m| bert_head(ps, m.row(0).to_vec()))
        .collect()
}

/// BERT-style classifier logits for a batch of samples (transient scratch
/// pool).
#[deprecated(note = "hold a `crate::engine::BertSession` (one per worker) \
                     instead")]
#[allow(deprecated)]
pub fn bert_logits_batch(ps: &ParamStore, cfg: &TextConfig,
                         token_seqs: &[Vec<i32>], seed: u64, workers: usize)
                         -> Result<Vec<Vec<f32>>> {
    let mut pool = ScratchPool::new();
    bert_logits_batch_pooled(ps, cfg, token_seqs, seed, workers, &mut pool)
}

/// L2-normalize a feature vector in place.
pub fn l2_normalize(v: &mut [f32]) {
    let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt() + 1e-6;
    for x in v.iter_mut() {
        *x /= n;
    }
}

/// CLIP text embedding for one caption.
pub fn clip_text_embed(ps: &ParamStore, tokens: &[i32], dim: usize,
                       depth: usize, heads: usize, embed_dim: usize,
                       rng: &mut Rng) -> Result<Vec<f32>> {
    let plan = vec![tokens.len(); depth + 1];
    let f = text_features(ps, "txt.", tokens, dim, depth, heads,
                          MergeMode::None, plan, rng)?;
    let fm = Mat::from_vec(1, f.len(), f);
    let mut e = dense(&fm, &ps.mat2("proj.txt")?, None).data;
    debug_assert_eq!(e.len(), embed_dim);
    l2_normalize(&mut e);
    Ok(e)
}
