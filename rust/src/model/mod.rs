//! CPU reference models + FLOPs cost model + parameter loading.
//!
//! The CPU reference transformer mirrors `python/compile/model.py` exactly
//! (parity asserted against `artifacts/testvectors.json`); it runs the
//! r-sweep experiments where compiling one PJRT artifact per (mode, r)
//! point would be wasteful, while the PJRT runtime serves the fixed
//! production variants.

pub mod encoder;
pub mod flops;
pub mod params;
pub mod text;
pub mod vit;

#[allow(deprecated)]
pub use encoder::{encoder_forward_batch, encoder_forward_batch_pooled,
                  encoder_forward_scratch};
pub use encoder::{attention, attention_into, encoder_forward,
                  encoder_forward_slot, encoder_forward_slots,
                  encoder_layers, EncoderCfg, EncoderScratch,
                  ResolvedEncoder, ScratchPool, SeqSlot};
pub use flops::{block_flops, encoder_flops, flops_speedup, vit_gflops};
pub use params::{synthetic_bert_store, synthetic_mm_store,
                 synthetic_vit_store, MatSpan, ParamEntry, ParamStore,
                 VecSpan, MM_TEXT_DEPTH, MM_TEXT_DIM, MM_VQA_HIDDEN};
#[allow(deprecated)]
pub use text::{bert_logits_batch, bert_logits_batch_pooled};
pub use text::{bert_logits, clip_text_embed, embed_tokens, text_features};
pub use vit::ViTModel;

use std::path::Path;

use crate::error::Result;

/// Load a model's parameter store from `artifacts/params/<name>.{bin,json}`.
pub fn load_model_params(artifacts: &Path, name: &str) -> Result<ParamStore> {
    ParamStore::load(
        &artifacts.join("params").join(format!("{name}.bin")),
        &artifacts.join("params").join(format!("{name}.json")),
    )
}
