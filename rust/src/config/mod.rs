//! Typed configuration system: model configs (mirroring
//! `python/compile/common.py`), merge settings, serving policies.
//!
//! Configs load from JSON files or CLI flags; every experiment binary and
//! the `pitome` CLI share these types.

use crate::merge::{merge_plan, MergeMode};

/// Default ToFu prune threshold: matched pairs whose cosine similarity
/// falls below this prune instead of merging.  Previously hardcoded in
/// `merge_step`; lifted here so benches and eval sweeps can vary it
/// (`ViTConfig::tofu_threshold` / `TextConfig::tofu_threshold` /
/// `MergeCtx::tofu_threshold`).  The cross-language testvectors were
/// generated at 0.45, so that stays the default.
pub const DEFAULT_TOFU_PRUNE_THRESHOLD: f32 = 0.45;

/// ViT family config — must mirror `compile.common.ViTConfig` so the Rust
/// CPU reference and the AOT artifacts agree on shapes and plans.
#[derive(Clone, Debug)]
pub struct ViTConfig {
    /// model name tag
    pub name: String,
    /// input image side
    pub image_size: usize,
    /// square patch side
    pub patch_size: usize,
    /// embedding dim
    pub dim: usize,
    /// transformer depth
    pub depth: usize,
    /// attention heads
    pub heads: usize,
    /// MLP expansion ratio
    pub mlp_ratio: f64,
    /// classifier classes
    pub num_classes: usize,
    /// merge algorithm
    pub merge_mode: String,
    /// keep-ratio per layer
    pub merge_r: f64,
    /// restrict merging to these blocks (None = all)
    pub merge_layers: Option<Vec<usize>>,
    /// proportional attention on/off
    pub prop_attn: bool,
    /// ToFu prune threshold (only used by mode "tofu")
    pub tofu_threshold: f32,
}

impl Default for ViTConfig {
    fn default() -> Self {
        ViTConfig {
            name: "vit-ti".into(),
            image_size: 32,
            patch_size: 4,
            dim: 64,
            depth: 4,
            heads: 4,
            mlp_ratio: 2.0,
            num_classes: 10,
            merge_mode: "none".into(),
            merge_r: 1.0,
            merge_layers: None,
            prop_attn: true,
            tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
        }
    }
}

impl ViTConfig {
    /// Paper-scale presets used by the FLOPs cost model (Table 6 backbones).
    pub fn preset(name: &str) -> Option<ViTConfig> {
        let (dim, depth, heads, img, patch) = match name {
            "vit-ti" => (64, 4, 4, 32, 4),
            "deit-t" => (192, 12, 3, 224, 16),
            "deit-s" => (384, 12, 6, 224, 16),
            "deit-b" => (768, 12, 12, 224, 16),
            "mae-l" => (1024, 24, 16, 224, 16),
            "mae-h" => (1280, 32, 16, 224, 14),
            _ => return None,
        };
        Some(ViTConfig {
            name: name.into(),
            image_size: img,
            patch_size: patch,
            dim,
            depth,
            heads,
            mlp_ratio: if name == "vit-ti" { 2.0 } else { 4.0 },
            num_classes: if name == "vit-ti" { 10 } else { 1000 },
            ..Default::default()
        })
    }

    /// Patch vector length.
    pub fn patch_dim(&self) -> usize {
        self.patch_size * self.patch_size
    }

    /// Patch count.
    pub fn num_patches(&self) -> usize {
        (self.image_size / self.patch_size).pow(2)
    }

    /// Tokens incl. CLS.
    pub fn n_tokens(&self) -> usize {
        self.num_patches() + 1
    }

    /// Head dim.
    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// MLP hidden width.
    pub fn mlp_hidden(&self) -> usize {
        (self.dim as f64 * self.mlp_ratio) as usize
    }

    /// Parsed merge mode.
    pub fn mode(&self) -> MergeMode {
        MergeMode::parse(&self.merge_mode).unwrap_or(MergeMode::None)
    }

    /// Static token plan (mirror of `ViTConfig.plan()` in python).
    pub fn plan(&self) -> Vec<usize> {
        if self.mode() == MergeMode::None || self.merge_r >= 1.0 {
            return vec![self.n_tokens(); self.depth + 1];
        }
        merge_plan(self.n_tokens(), self.merge_r, self.depth, 1,
                   self.merge_layers.as_deref())
    }
}

/// Text model config — mirror of `compile.common.TextConfig`.
#[derive(Clone, Debug)]
pub struct TextConfig {
    /// model tag
    pub name: String,
    /// vocabulary size
    pub vocab_size: usize,
    /// sequence length (without CLS)
    pub seq_len: usize,
    /// embedding dim
    pub dim: usize,
    /// depth
    pub depth: usize,
    /// heads
    pub heads: usize,
    /// MLP ratio
    pub mlp_ratio: f64,
    /// output classes
    pub num_classes: usize,
    /// merge algorithm
    pub merge_mode: String,
    /// keep-ratio
    pub merge_r: f64,
    /// blocks that merge (paper: first three)
    pub merge_layers: Option<Vec<usize>>,
    /// proportional attention
    pub prop_attn: bool,
    /// ToFu prune threshold (only used by mode "tofu")
    pub tofu_threshold: f32,
}

impl Default for TextConfig {
    fn default() -> Self {
        TextConfig {
            name: "bert-small".into(),
            vocab_size: 512,
            seq_len: 128,
            dim: 64,
            depth: 4,
            heads: 4,
            mlp_ratio: 2.0,
            num_classes: 2,
            merge_mode: "none".into(),
            merge_r: 1.0,
            merge_layers: Some(vec![0, 1, 2]),
            prop_attn: true,
            tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
        }
    }
}

impl TextConfig {
    /// Tokens incl. CLS.
    pub fn n_tokens(&self) -> usize {
        self.seq_len + 1
    }

    /// Parsed merge mode.
    pub fn mode(&self) -> MergeMode {
        MergeMode::parse(&self.merge_mode).unwrap_or(MergeMode::None)
    }

    /// Static token plan.
    pub fn plan(&self) -> Vec<usize> {
        if self.mode() == MergeMode::None || self.merge_r >= 1.0 {
            return vec![self.n_tokens(); self.depth + 1];
        }
        merge_plan(self.n_tokens(), self.merge_r, self.depth, 1,
                   self.merge_layers.as_deref())
    }
}

/// Serving policy for the coordinator.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// max batch size (must match an available artifact batch)
    pub max_batch: usize,
    /// max time to hold a partial batch, microseconds
    pub batch_timeout_us: u64,
    /// bounded queue capacity (admission control / backpressure)
    pub queue_capacity: usize,
    /// number of worker tasks
    pub workers: usize,
    /// per-worker span-ring capacity in events; 0 (the default)
    /// disables tracing entirely — no rings are allocated and the
    /// serving path records nothing
    pub trace_capacity: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 8,
            batch_timeout_us: 2_000,
            queue_capacity: 1024,
            workers: 1,
            trace_capacity: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_flat() {
        let c = ViTConfig::default();
        assert_eq!(c.plan(), vec![65; 5]);
    }

    #[test]
    fn merged_plan_shrinks() {
        let c = ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                            ..Default::default() };
        let p = c.plan();
        assert_eq!(p[0], 65);
        assert!(p[4] < 65);
    }

    #[test]
    fn presets_exist() {
        for name in ["deit-t", "deit-s", "mae-l", "mae-h"] {
            let c = ViTConfig::preset(name).unwrap();
            assert!(c.n_tokens() > 100);
        }
        assert!(ViTConfig::preset("nope").is_none());
    }

    #[test]
    fn text_plan_only_first_layers() {
        let c = TextConfig { merge_mode: "pitome".into(), merge_r: 0.8,
                             ..Default::default() };
        let p = c.plan();
        assert!(p[3] < p[0]);
        assert_eq!(p[3], p[4]);
    }
}
