//! Random pruning baseline.

use super::plan::MergePlan;
use crate::data::Rng;

/// Drop k random non-protected tokens (gate 0 on an empty B = pure prune).
pub fn random_plan(n: usize, k: usize, protect_first: usize, rng: &mut Rng)
    -> MergePlan {
    // Fisher-Yates permutation of the candidate indices
    let mut perm: Vec<usize> = (protect_first..n).collect();
    for i in (1..perm.len()).rev() {
        let j = rng.next_below((i + 1) as u64) as usize;
        perm.swap(i, j);
    }
    let a: Vec<usize> = perm[..k].to_vec();
    let mut protect: Vec<usize> = (0..protect_first).collect();
    protect.extend_from_slice(&perm[k..]);
    protect.sort_unstable();
    MergePlan { protect, a, b: vec![], dst: vec![0; k], gate: vec![0.0; k] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::plan::apply_plan;
    use crate::tensor::Mat;

    #[test]
    fn drops_exactly_k() {
        let mut rng = Rng::new(8);
        let plan = random_plan(20, 6, 1, &mut rng);
        plan.validate(20).unwrap();
        assert_eq!(plan.n_out(), 14);
        assert!(plan.protect.contains(&0));
        let x = Mat::from_fn(20, 3, |i, j| (i * 3 + j) as f32);
        let (out, sizes) = apply_plan(&x, &vec![1.0; 20], &plan);
        assert_eq!(out.rows, 14);
        assert_eq!(sizes.len(), 14);
    }

    #[test]
    fn different_seeds_different_drops() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        let p1 = random_plan(30, 8, 1, &mut r1);
        let p2 = random_plan(30, 8, 1, &mut r2);
        assert_ne!(p1.a, p2.a);
    }
}
