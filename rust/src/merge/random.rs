//! Random pruning baseline.

use super::plan::{MergePlan, PlanScratch};
use crate::data::Rng;

/// Drop k random non-protected tokens (allocating wrapper over
/// [`random_plan_into`]).
pub fn random_plan(n: usize, k: usize, protect_first: usize, rng: &mut Rng)
    -> MergePlan {
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    random_plan_into(n, k, protect_first, rng, &mut scratch, &mut plan);
    plan
}

/// Drop k random non-protected tokens into a reusable [`MergePlan`] +
/// [`PlanScratch`] — gate 0 on an empty B = pure prune; allocation-free
/// once warm (see the in-place lifecycle in [`super::plan`]).
pub fn random_plan_into(n: usize, k: usize, protect_first: usize,
                        rng: &mut Rng, s: &mut PlanScratch,
                        out: &mut MergePlan) {
    out.clear();
    // Fisher-Yates permutation of the candidate indices
    s.merge_idx.clear();
    s.merge_idx.extend(protect_first..n);
    for i in (1..s.merge_idx.len()).rev() {
        let j = rng.next_below((i + 1) as u64) as usize;
        s.merge_idx.swap(i, j);
    }
    out.a.extend_from_slice(&s.merge_idx[..k]);
    out.protect.extend(0..protect_first);
    out.protect.extend_from_slice(&s.merge_idx[k..]);
    out.protect.sort_unstable();
    out.dst.resize(k, 0);
    out.gate.resize(k, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::plan::apply_plan;
    use crate::tensor::Mat;

    #[test]
    fn drops_exactly_k() {
        let mut rng = Rng::new(8);
        let plan = random_plan(20, 6, 1, &mut rng);
        plan.validate(20).unwrap();
        assert_eq!(plan.n_out(), 14);
        assert!(plan.protect.contains(&0));
        let x = Mat::from_fn(20, 3, |i, j| (i * 3 + j) as f32);
        let (out, sizes) = apply_plan(&x, &vec![1.0; 20], &plan);
        assert_eq!(out.rows, 14);
        assert_eq!(sizes.len(), 14);
    }

    #[test]
    fn different_seeds_different_drops() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        let p1 = random_plan(30, 8, 1, &mut r1);
        let p2 = random_plan(30, 8, 1, &mut r2);
        assert_ne!(p1.a, p2.a);
    }
}
