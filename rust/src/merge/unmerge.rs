//! Unmerge: the paper's "Limitations and Future Works" extension — a
//! decoder-side mechanism that expands a merged token set back to the full
//! resolution (needed for generative/segmentation heads).
//!
//! Two pieces:
//! - [`unmerge`] inverts one [`MergePlan`]: every original token receives
//!   the value of the merged token it was absorbed into (broadcast
//!   semantics, the standard ToMe-SD choice).
//! - [`MergeTracker`] composes plans across layers, maintaining the map
//!   original-token -> final-token so the full stack can be unmerged in
//!   one gather (and so merged regions can be *visualized*, Fig. 1/11).

use super::plan::MergePlan;
use crate::tensor::Mat;

/// Expand merged tokens (n_out, h) back to (n_in, h) under `plan`:
/// protected tokens copy their row; merged A tokens copy their
/// destination's row; pruned A tokens (gate 0) receive zeros.
// lint: allow(alloc) reason=offline reconstruction utility, not on the serving path
pub fn unmerge(merged: &Mat, plan: &MergePlan, n_in: usize) -> Mat {
    let h = merged.cols;
    let mut out = Mat::zeros(n_in, h);
    for (oi, &src) in plan.protect.iter().enumerate() {
        out.row_mut(src).copy_from_slice(merged.row(oi));
    }
    let off = plan.protect.len();
    for (bi, &src) in plan.b.iter().enumerate() {
        out.row_mut(src).copy_from_slice(merged.row(off + bi));
    }
    for (ai, &src) in plan.a.iter().enumerate() {
        if plan.gate[ai] == 0.0 {
            continue; // pruned: stays zero
        }
        let from = off + plan.dst[ai];
        let row: Vec<f32> = merged.row(from).to_vec();
        out.row_mut(src).copy_from_slice(&row);
    }
    out
}

/// Tracks the composition of merge plans across encoder layers.
#[derive(Clone, Debug, Default)]
pub struct MergeTracker {
    /// for each original token, its current row index (None = pruned)
    map: Vec<Option<usize>>,
}

impl MergeTracker {
    /// Start tracking `n` tokens.
    // lint: allow(alloc) reason=tracker setup per sequence, off the steady-state path
    pub fn new(n: usize) -> Self {
        MergeTracker { map: (0..n).map(Some).collect() }
    }

    /// Record one merge plan applied to the *current* token set.
    // lint: allow(alloc) reason=eval-only tracker bookkeeping
    pub fn push(&mut self, plan: &MergePlan) {
        // current index -> next index
        let n_cur = plan.protect.len() + plan.a.len() + plan.b.len();
        let mut next = vec![None; n_cur];
        for (oi, &src) in plan.protect.iter().enumerate() {
            next[src] = Some(oi);
        }
        let off = plan.protect.len();
        for (bi, &src) in plan.b.iter().enumerate() {
            next[src] = Some(off + bi);
        }
        for (ai, &src) in plan.a.iter().enumerate() {
            next[src] = if plan.gate[ai] == 0.0 {
                None
            } else {
                Some(off + plan.dst[ai])
            };
        }
        for slot in self.map.iter_mut() {
            if let Some(cur) = *slot {
                *slot = next[cur];
            }
        }
    }

    /// Final row index of each original token (None = pruned away).
    pub fn assignment(&self) -> &[Option<usize>] {
        &self.map
    }

    /// Unmerge the final representation back to original resolution in one
    /// gather; pruned tokens receive zeros.
    pub fn expand(&self, final_tokens: &Mat) -> Mat {
        let mut out = Mat::zeros(self.map.len(), final_tokens.cols);
        for (orig, slot) in self.map.iter().enumerate() {
            if let Some(row) = slot {
                out.row_mut(orig).copy_from_slice(final_tokens.row(*row));
            }
        }
        out
    }

    /// Group id per original token (final row index as group label),
    /// usable directly as a [`crate::graph::Partition`] assignment after
    /// compaction — and for ASCII visualization of merged regions.
    // lint: allow(alloc) reason=eval-only readout of the final token map
    pub fn groups(&self) -> Vec<usize> {
        let n_final = self
            .map
            .iter()
            .filter_map(|s| *s)
            .max()
            .map_or(0, |m| m + 1);
        self.map
            .iter()
            .map(|s| s.unwrap_or(n_final)) // pruned tokens share a sink id
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::merge::energy::energy_scores;
    use crate::merge::pitome::{ordered_bsm_plan, Split};
    use crate::merge::plan::apply_plan;

    fn rand_mat(n: usize, h: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, h, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32)
    }

    #[test]
    fn unmerge_restores_protected_rows_exactly() {
        let x = rand_mat(15, 4, 1);
        let e = energy_scores(&x, 0.4);
        let mut rng = Rng::new(2);
        let plan = ordered_bsm_plan(&x, &e, 4, 1, Split::Alternate, true, &mut rng);
        let (merged, _) = apply_plan(&x, &vec![1.0; 15], &plan);
        let restored = unmerge(&merged, &plan, 15);
        for &p in &plan.protect {
            assert_eq!(restored.row(p), x.row(p), "protected row {p} changed");
        }
        // merged sources share their destination's value
        for (ai, &a) in plan.a.iter().enumerate() {
            let b = plan.b[plan.dst[ai]];
            assert_eq!(restored.row(a), restored.row(b));
        }
    }

    #[test]
    fn tracker_composes_two_layers() {
        let x0 = rand_mat(15, 4, 3);
        let mut tracker = MergeTracker::new(15);
        let mut rng = Rng::new(4);
        let e0 = energy_scores(&x0, 0.4);
        let p0 = ordered_bsm_plan(&x0, &e0, 3, 1, Split::Alternate, true, &mut rng);
        let (x1, s1) = apply_plan(&x0, &vec![1.0; 15], &p0);
        tracker.push(&p0);
        let e1 = energy_scores(&x1, 0.3);
        let p1 = ordered_bsm_plan(&x1, &e1, 2, 1, Split::Alternate, true, &mut rng);
        let (x2, _) = apply_plan(&x1, &s1, &p1);
        tracker.push(&p1);

        // expand maps every original token to a final row
        let full = tracker.expand(&x2);
        assert_eq!(full.rows, 15);
        // every original token's final value equals x2[assignment]
        for (orig, slot) in tracker.assignment().iter().enumerate() {
            let row = slot.expect("no pruning in this plan");
            assert_eq!(full.row(orig), x2.row(row));
        }
        // group count equals final token count
        let groups = tracker.groups();
        let distinct: std::collections::HashSet<_> = groups.iter().collect();
        assert_eq!(distinct.len(), x2.rows);
    }

    #[test]
    fn tracker_handles_pruning() {
        // tofu-like plan with a pruned token
        let plan = MergePlan {
            protect: vec![0, 2],
            a: vec![3, 4],
            b: vec![1],
            dst: vec![0, 0],
            gate: vec![1.0, 0.0],
        };
        let mut t = MergeTracker::new(5);
        t.push(&plan);
        assert_eq!(t.assignment()[3], Some(2)); // merged into b slot
        assert_eq!(t.assignment()[4], None);    // pruned
        let final_tokens = Mat::from_fn(3, 2, |i, _| i as f32);
        let full = t.expand(&final_tokens);
        assert_eq!(full.row(4), &[0.0, 0.0]);
    }
}
