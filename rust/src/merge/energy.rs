//! Energy score (Eq. 4) — the paper's redundancy indicator.
//!
//! `E_i = 1/N * sum_{j != i} f_m(cos(v_i, v_j))` with the ELU-style clamp
//! `f_m(x) = x if x >= m else alpha * (exp(x - m) - 1)`.
//! Numerics mirror `ref.energy_scores` (eps 1e-6 normalization, diagonal
//! masked) to float tolerance.

use crate::tensor::{CosineGram, Mat};

/// ELU floor coefficient (paper uses alpha = 1).
pub const ALPHA: f32 = 1.0;

/// The margin clamp of Eq. (4).
#[inline]
pub fn f_margin(x: f32, margin: f32) -> f32 {
    if x >= margin {
        x
    } else {
        ALPHA * ((x - margin).exp() - 1.0)
    }
}

/// Layer-dependent margin schedule `m = 0.9 - 0.9 * l / L` (Sec 3.2).
pub fn layer_margin(layer: usize, num_layers: usize) -> f32 {
    let base = 0.9f32;
    base - base * layer as f32 / (num_layers.max(1) as f32)
}

/// Energy scores for key features `kf` (n, h): convenience wrapper that
/// builds its own Gram.  The merge hot path ([`crate::merge::merge_step`])
/// instead builds **one** [`CosineGram`] per step and calls
/// [`energy_from_gram`] so the same Gram also drives bipartite matching.
pub fn energy_scores(kf: &Mat, margin: f32) -> Vec<f32> {
    energy_from_gram(&CosineGram::build(kf), margin)
}

/// Energy scores from a precomputed shared Gram (allocating wrapper over
/// [`energy_from_gram_into`]).
// lint: allow(alloc) reason=allocating convenience wrapper; hot callers use the _into form
pub fn energy_from_gram(g: &CosineGram, margin: f32) -> Vec<f32> {
    let mut e = Vec::new();
    energy_from_gram_into(g, margin, &mut e);
    e
}

/// Energy scores from a precomputed shared Gram into a reusable buffer
/// (the single-pass pipeline; allocation-free once `e` has seen its
/// largest length).
///
/// O(n^2) over the symmetric Gram: each pair's margin-clamped similarity is
/// read once and credited to both endpoints, mirroring the two-sided
/// traversal the original O(n^2 h) implementation used — so results match
/// the old two-pass path to float tolerance.
pub fn energy_from_gram_into(g: &CosineGram, margin: f32, e: &mut Vec<f32>) {
    let n = g.n();
    e.clear();
    e.resize(n, 0f32);
    for i in 0..n {
        let row = g.w.row(i);
        for j in (i + 1)..n {
            let f = f_margin(row[j], margin);
            e[i] += f;
            e[j] += f;
        }
    }
    let inv = 1.0 / n as f32;
    for v in e.iter_mut() {
        *v *= inv;
    }
}

/// Energy scores given a precomputed cosine matrix (used when the caller
/// already built W for matching — avoids the second Gram pass).
// lint: allow(alloc) reason=allocating convenience wrapper; hot callers use the _into form
pub fn energy_from_cosine(w: &Mat, margin: f32) -> Vec<f32> {
    let n = w.rows;
    let mut e = vec![0f32; n];
    for i in 0..n {
        let row = w.row(i);
        let mut acc = 0f32;
        for (j, &wij) in row.iter().enumerate() {
            if j == i {
                continue;
            }
            acc += f_margin(wij, margin);
        }
        e[i] = acc / n as f32;
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::tensor::cosine_matrix;

    #[test]
    fn f_margin_branches() {
        let m = 0.5;
        // at/above margin: identity
        assert!((f_margin(m, m) - m).abs() < 1e-6);
        assert!((f_margin(0.9, m) - 0.9).abs() < 1e-6);
        // below margin: ELU floor, small negative near the margin,
        // approaching -alpha far below
        let just_below = f_margin(m - 1e-4, m);
        assert!(just_below < 0.0 && just_below > -1e-3, "{just_below}");
        assert!(f_margin(-1.0, m) > -ALPHA - 1e-6);
        assert!(f_margin(-1.0, m) < -0.7);
    }

    #[test]
    fn margin_schedule_decreases() {
        let l = 12;
        for i in 1..l {
            assert!(layer_margin(i, l) < layer_margin(i - 1, l));
        }
        assert!((layer_margin(0, l) - 0.9).abs() < 1e-6);
    }

    #[test]
    fn clustered_tokens_have_higher_energy() {
        // 20 near-identical tokens + 3 scattered ones
        let mut rng = Rng::new(4);
        let h = 8;
        let center: Vec<f32> = (0..h).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        let m = Mat::from_fn(23, h, |i, j| {
            if i < 20 {
                center[j] + 0.01 * (rng.next_f64() as f32 - 0.5)
            } else {
                -(center[j]) + 2.0 * (rng.next_f64() as f32 - 0.5)
            }
        });
        let e = energy_scores(&m, 0.5);
        let min_cluster = e[..20].iter().cloned().fold(f32::INFINITY, f32::min);
        let max_iso = e[20..].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(min_cluster > max_iso, "{min_cluster} vs {max_iso}");
    }

    #[test]
    fn energy_from_gram_matches_naive_two_pass() {
        // reference: the pre-refactor implementation (normalize + direct
        // per-pair dot products, no shared Gram)
        fn naive(kf: &Mat, margin: f32) -> Vec<f32> {
            let n = kf.rows;
            let kn = crate::tensor::normalize_rows(kf);
            let mut e = vec![0f32; n];
            for i in 0..n {
                let ri = kn.row(i);
                for j in (i + 1)..n {
                    let dot: f32 = ri.iter().zip(kn.row(j)).map(|(a, b)| a * b).sum();
                    let f = f_margin(dot, margin);
                    e[i] += f;
                    e[j] += f;
                }
            }
            let inv = 1.0 / n as f32;
            for v in e.iter_mut() {
                *v *= inv;
            }
            e
        }
        let mut rng = Rng::new(17);
        for &(n, h) in &[(5usize, 3usize), (23, 8), (40, 17)] {
            let m = Mat::from_fn(n, h, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
            for margin in [-0.2f32, 0.3, 0.7] {
                let want = naive(&m, margin);
                let got = energy_from_gram(&CosineGram::build(&m), margin);
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-5, "n={n} h={h} m={margin}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn energy_from_cosine_matches_direct() {
        let mut rng = Rng::new(9);
        let m = Mat::from_fn(12, 6, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let w = cosine_matrix(&m);
        let e1 = energy_scores(&m, 0.3);
        let e2 = energy_from_cosine(&w, 0.3);
        for (a, b) in e1.iter().zip(&e2) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
