//! ToMe parity-split BSM (Bolya et al. 2023) and ToFu (prune threshold).

use super::plan::{MergePlan, PlanScratch};
use crate::tensor::{argsort_desc_into, CosineGram, Mat};

/// ToMe plan from key features (convenience wrapper: builds its own
/// [`CosineGram`]; the merge hot path shares one via [`tome_plan_gram`]).
pub fn tome_plan(kf: &Mat, k: usize, protect_first: usize,
                 prune_threshold: Option<f32>) -> MergePlan {
    tome_plan_gram(&CosineGram::build(kf), k, protect_first, prune_threshold)
}

/// ToMe plan from a precomputed shared Gram (allocating wrapper over
/// [`tome_plan_gram_into`]).
pub fn tome_plan_gram(g: &CosineGram, k: usize, protect_first: usize,
                      prune_threshold: Option<f32>) -> MergePlan {
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    tome_plan_gram_into(g, k, protect_first, prune_threshold, &mut scratch,
                        &mut plan);
    plan
}

/// ToMe plan from a precomputed shared Gram into a reusable
/// [`MergePlan`] + [`PlanScratch`] (allocation-free once warm; see the
/// in-place lifecycle in [`super::plan`]): candidates split by index
/// parity; the k most-similar A tokens merge into their best B match.
/// With `prune_threshold`, low-similarity pairs prune instead of merging
/// (ToFu).
pub fn tome_plan_gram_into(g: &CosineGram, k: usize, protect_first: usize,
                           prune_threshold: Option<f32>, s: &mut PlanScratch,
                           out: &mut MergePlan) {
    let n = g.n();
    out.clear();
    // parity split of the candidate range [protect_first, n)
    s.a_all.clear();
    s.a_all.extend((protect_first..n).step_by(2));
    out.b.extend((protect_first + 1..n).step_by(2));
    assert!(k <= s.a_all.len(), "k={k} exceeds |A|={}", s.a_all.len());

    s.best.clear();
    s.best.resize(s.a_all.len(), f32::NEG_INFINITY);
    s.dst_all.clear();
    s.dst_all.resize(s.a_all.len(), 0);
    for (ai, &aidx) in s.a_all.iter().enumerate() {
        if let Some((bi, d)) = g.best_match(aidx, &out.b, 0) {
            s.best[ai] = d;
            s.dst_all[ai] = bi;
        }
    }
    argsort_desc_into(&s.best, &mut s.pair_rank);
    for &p in s.pair_rank.iter().take(k) {
        out.a.push(s.a_all[p]);
        out.dst.push(s.dst_all[p]);
        out.gate.push(match prune_threshold {
            Some(t) if s.best[p] < t => 0.0,
            _ => 1.0,
        });
    }
    out.protect.extend(0..protect_first);
    for &p in s.pair_rank.iter().skip(k) {
        out.protect.push(s.a_all[p]);
    }
    out.protect.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::merge::plan::apply_plan;

    #[test]
    fn parity_split_respected() {
        let mut rng = Rng::new(5);
        let kf = Mat::from_fn(21, 8, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let plan = tome_plan(&kf, 5, 1, None);
        plan.validate(21).unwrap();
        // A indices are odd candidate slots (1,3,5,...), B even (2,4,6,...)
        for &i in &plan.a {
            assert_eq!((i - 1) % 2, 0, "A index {i} not on even candidate slot");
        }
        for &i in &plan.b {
            assert_eq!((i - 1) % 2, 1, "B index {i} not on odd candidate slot");
        }
        assert_eq!(plan.n_out(), 16);
    }

    #[test]
    fn tofu_prunes_dissimilar() {
        // two orthogonal groups: parity split forces cross-group pairs with
        // low similarity -> ToFu should gate them to prune.
        // two orthogonal groups: parity split forces cross-group pairs
        let kf = Mat::from_fn(9, 2, |i, j| {
            if i == 0 { 0.5 }
            else if i % 2 == 1 { if j == 0 { 1.0 } else { 0.0 } }
            else if j == 1 { 1.0 } else { 0.0 }
        });
        let _ = kf;
        let plan = tome_plan(&kf, 2, 1, Some(0.9));
        let total_gate: f32 = plan.gate.iter().sum();
        assert!(total_gate < 2.0, "expected some prunes, gates {:?}", plan.gate);
        let (out, sizes) = apply_plan(&kf, &vec![1.0; 9], &plan);
        assert_eq!(out.rows, 7);
        // pruned mass lost
        assert!(sizes.iter().sum::<f32>() <= 9.0);
    }
}
