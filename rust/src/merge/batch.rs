//! Batched merging: run merge steps for a batch of sequences across
//! scoped worker threads (std::thread only — DESIGN.md §11 allows no
//! external thread-pool crates).
//!
//! # API
//!
//! * [`merge_step_batch`] — one [`merge_step`](super::merge_step) per
//!   [`BatchSeq`], fanned out over up to `workers` threads.  Each sequence
//!   owns a deterministic per-item RNG seed, so results are independent of
//!   thread scheduling and identical to the serial path for every
//!   deterministic mode (PiToMe/ToMe/ToFu/DCT/DiffRate); stochastic modes
//!   (random split / random pruning) are driven by the per-item seed.
//! * [`parallel_map`] / [`parallel_map_mut`] — the underlying scoped
//!   fan-out helpers ([`merge_step_batch`] runs on [`parallel_map`];
//!   [`parallel_map_mut`] is the general in-place variant).
//! * [`parallel_map_mut_ctx`] / [`parallel_for2_mut_ctx`] — fan-outs
//!   where each worker thread additionally owns one reusable context
//!   (its `EncoderScratch`), so buffers persist across every item the
//!   worker processes instead of being reallocated per item.  The `for2`
//!   form pairs two slices (input slots + output buffers) and collects
//!   nothing, which is what the engine's slot-based batch driver
//!   (`model::encoder::encoder_forward_slots`) runs on — the fan-out
//!   itself allocates nothing.
//! * [`FragQueue`] — a work-stealing fragment queue over a pair of
//!   slices: concurrent workers `pop` disjoint `(base, items, outs)`
//!   fragments until the batch is drained.  Unlike the chunked fan-outs
//!   above (static assignment), fragments go to whichever worker asks
//!   next, so a slow item cannot strand the rest of its chunk behind one
//!   worker — this is what the joint vision+text tower driver
//!   (`model::encoder::encoder_forward_towers`) steals across towers
//!   with.
//!
//! Each sequence still builds exactly one cosine Gram, on whichever worker
//! thread processes it — batching composes with the shared-Gram pipeline
//! rather than replacing it.

use std::sync::Mutex;

use super::{merge_step, MergeCtx, MergeMode};
use crate::data::Rng;
use crate::tensor::Mat;

/// One sequence in a merge batch: the per-sequence context plus the seed
/// that makes stochastic modes deterministic under any thread schedule.
pub struct BatchSeq<'a> {
    /// per-sequence merge context
    pub ctx: MergeCtx<'a>,
    /// RNG seed for this sequence's merge step
    pub seed: u64,
}

/// Number of worker threads to use when the caller has no preference.
pub fn recommended_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` using up to `workers` scoped threads, preserving
/// order.  `workers <= 1` (or a single item) runs inline with no spawns.
// lint: allow(alloc) reason=batch driver: worker/result collects amortize over the whole batch
pub fn parallel_map<T, U, F>(items: &[T], workers: usize, f: &F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (ci, (ichunk, ochunk)) in
            items.chunks(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            s.spawn(move || {
                for (off, (item, slot)) in
                    ichunk.iter().zip(ochunk.iter_mut()).enumerate()
                {
                    *slot = Some(f(ci * chunk + off, item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// In-place variant of [`parallel_map`]: `f` mutates each item and its
/// return values are collected in order.
// lint: allow(alloc) reason=batch driver: worker/result collects amortize over the whole batch
pub fn parallel_map_mut<T, U, F>(items: &mut [T], workers: usize, f: &F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (ci, (ichunk, ochunk)) in
            items.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            s.spawn(move || {
                for (off, (item, slot)) in
                    ichunk.iter_mut().zip(ochunk.iter_mut()).enumerate()
                {
                    *slot = Some(f(ci * chunk + off, item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// Like [`parallel_map_mut`], but each worker thread owns one reusable
/// context from `ctxs` (the worker count is `ctxs.len()`): chunk `ci`
/// runs with `ctxs[ci]`, so a context is reused for every item of its
/// chunk and survives the call for the caller to reuse again.  This is
/// how the batch encoder gives each worker thread a persistent
/// `EncoderScratch`.
// lint: allow(alloc) reason=batch driver: worker/result collects amortize over the whole batch
pub fn parallel_map_mut_ctx<T, U, C, F>(items: &mut [T], ctxs: &mut [C],
                                        f: &F) -> Vec<U>
where
    T: Send,
    U: Send,
    C: Send,
    F: Fn(usize, &mut T, &mut C) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    assert!(!ctxs.is_empty(), "parallel_map_mut_ctx needs at least one ctx");
    let workers = ctxs.len().min(n);
    if workers == 1 {
        let ctx = &mut ctxs[0];
        return items
            .iter_mut()
            .enumerate()
            .map(|(i, t)| f(i, t, ctx))
            .collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (ci, ((ichunk, ochunk), ctx)) in items
            .chunks_mut(chunk)
            .zip(out.chunks_mut(chunk))
            .zip(ctxs.iter_mut())
            .enumerate()
        {
            s.spawn(move || {
                for (off, (item, slot)) in
                    ichunk.iter_mut().zip(ochunk.iter_mut()).enumerate()
                {
                    *slot = Some(f(ci * chunk + off, item, ctx));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// Two-slice variant of [`parallel_map_mut_ctx`] that collects nothing:
/// item `i` is the pair `(a[i], b[i])`, chunked identically across the
/// workers, and `f`'s work is written through the `&mut` references
/// instead of being returned — so the fan-out itself performs **zero**
/// heap allocations (no output `Vec`), which is what the engine's
/// slot-based batch driver (`model::encoder::encoder_forward_slots`)
/// needs for allocation-free serving.  With one ctx (or one item) the
/// loop runs inline on the caller's thread, no spawns.
pub fn parallel_for2_mut_ctx<A, B, C, F>(a: &mut [A], b: &mut [B],
                                         ctxs: &mut [C], f: &F)
where
    A: Send,
    B: Send,
    C: Send,
    F: Fn(usize, &mut A, &mut B, &mut C) + Sync,
{
    let n = a.len();
    assert_eq!(n, b.len(), "parallel_for2_mut_ctx slice length mismatch");
    if n == 0 {
        return;
    }
    assert!(!ctxs.is_empty(), "parallel_for2_mut_ctx needs at least one ctx");
    let workers = ctxs.len().min(n);
    if workers == 1 {
        let ctx = &mut ctxs[0];
        for (i, (ai, bi)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
            f(i, ai, bi, ctx);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (ci, ((achunk, bchunk), ctx)) in a
            .chunks_mut(chunk)
            .zip(b.chunks_mut(chunk))
            .zip(ctxs.iter_mut())
            .enumerate()
        {
            s.spawn(move || {
                for (off, (ai, bi)) in
                    achunk.iter_mut().zip(bchunk.iter_mut()).enumerate()
                {
                    f(ci * chunk + off, ai, bi, ctx);
                }
            });
        }
    });
}

/// Interior state of a [`FragQueue`]: the not-yet-handed-out tail of
/// the paired slices and the absolute index of its first element.
struct FragState<'a, A, B> {
    rest: Option<(&'a mut [A], &'a mut [B])>,
    base: usize,
}

/// A work-stealing fragment queue over two paired slices.
///
/// `new` takes ownership of the borrows; concurrent workers call
/// [`FragQueue::pop`] to receive disjoint fragments of up to `frag`
/// pairs — `(base_index, &mut items, &mut outs)` — until the slices are
/// exhausted.  Dynamic assignment (first worker to ask gets the next
/// fragment) is what makes cross-tower stealing work: an idle worker
/// can always grab the next fragment of *either* tower's queue.
///
/// The internal mutex is a **leaf lock** held only for the O(1)
/// `split_at_mut`; callers process fragments entirely outside it, so
/// queues never serialize the actual work and two queues can be polled
/// in any order without a lock-ordering hazard.
pub struct FragQueue<'a, A, B> {
    state: Mutex<FragState<'a, A, B>>,
    frag: usize,
}

impl<'a, A, B> FragQueue<'a, A, B> {
    /// Queue the paired slices for fragment-wise draining (`frag` pairs
    /// per pop, minimum 1).  The slices must be the same length.
    pub fn new(items: &'a mut [A], outs: &'a mut [B], frag: usize)
               -> FragQueue<'a, A, B> {
        assert_eq!(items.len(), outs.len(), "FragQueue slice length mismatch");
        let rest =
            if items.is_empty() { None } else { Some((items, outs)) };
        FragQueue {
            state: Mutex::new(FragState { rest, base: 0 }),
            frag: frag.max(1),
        }
    }

    /// Claim the next fragment: `(absolute base index, items, outs)`,
    /// or `None` once the queue is drained.
    pub fn pop(&self) -> Option<(usize, &'a mut [A], &'a mut [B])> {
        let mut g = self.state.lock().unwrap();
        let (items, outs) = g.rest.take()?;
        let k = self.frag.min(items.len());
        let (fa, ra) = items.split_at_mut(k);
        let (fb, rb) = outs.split_at_mut(k);
        let base = g.base;
        g.base += k;
        if !ra.is_empty() {
            g.rest = Some((ra, rb));
        }
        Some((base, fa, fb))
    }
}

/// Run one merge step per sequence across up to `workers` threads,
/// returning (merged tokens, new sizes) in input order.
pub fn merge_step_batch(mode: MergeMode, seqs: &[BatchSeq], workers: usize)
                        -> Vec<(Mat, Vec<f32>)> {
    parallel_map(seqs, workers, &|_, seq: &BatchSeq| {
        let mut rng = Rng::new(seq.seed);
        merge_step(mode, &seq.ctx, &mut rng)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD;

    fn rand_mat(n: usize, h: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, h, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32)
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..23).collect();
        for workers in [1, 2, 4, 7, 23, 64] {
            let out = parallel_map(&items, workers, &|i, &v| {
                assert_eq!(i, v);
                v * 2
            });
            assert_eq!(out, items.iter().map(|v| v * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_map_mut_mutates_in_place() {
        let mut items = vec![1u32; 10];
        let sums = parallel_map_mut(&mut items, 3, &|i, v| {
            *v += i as u32;
            *v
        });
        assert_eq!(items, (1..=10).map(|v| v as u32).collect::<Vec<_>>());
        assert_eq!(sums, items);
    }

    #[test]
    fn parallel_map_mut_ctx_reuses_one_ctx_per_chunk() {
        let mut items = vec![0u32; 23];
        for workers in [1usize, 2, 4, 7] {
            let mut ctxs = vec![0usize; workers];
            let out = parallel_map_mut_ctx(&mut items, &mut ctxs, &|i, v, c| {
                *c += 1; // items seen by this worker's context
                *v = i as u32;
                i
            });
            assert_eq!(out, (0..23).collect::<Vec<_>>());
            assert_eq!(items, (0..23u32).collect::<Vec<_>>());
            // every item was charged to exactly one context
            assert_eq!(ctxs.iter().sum::<usize>(), 23, "workers={workers}");
        }
    }

    #[test]
    fn parallel_for2_pairs_items_and_collects_nothing() {
        let mut xs = vec![0u32; 17];
        let mut ys = vec![0u32; 17];
        for workers in [1usize, 2, 3, 8] {
            xs.fill(0);
            ys.fill(0);
            let mut ctxs = vec![0usize; workers];
            parallel_for2_mut_ctx(&mut xs, &mut ys, &mut ctxs, &|i, x, y, c| {
                *x = i as u32;
                *y = 2 * i as u32;
                *c += 1;
            });
            assert_eq!(xs, (0..17).collect::<Vec<_>>(), "workers={workers}");
            assert_eq!(ys, (0..17).map(|v| 2 * v).collect::<Vec<_>>());
            assert_eq!(ctxs.iter().sum::<usize>(), 17, "workers={workers}");
        }
    }

    fn mk_ctx<'a>(x: &'a Mat, kf: &'a Mat, sizes: &'a [f32],
                  attn: &'a [f32]) -> MergeCtx<'a> {
        MergeCtx {
            x, kf, sizes, attn_cls: attn,
            margin: 0.45, k: 5, protect_first: 1,
            tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
        }
    }

    #[test]
    fn batch_matches_serial_for_deterministic_modes() {
        let b = 6;
        let n = 21;
        let mats: Vec<(Mat, Mat)> = (0..b)
            .map(|i| (rand_mat(n, 8, 100 + i), rand_mat(n, 8, 200 + i)))
            .collect();
        let sizes = vec![1.0f32; n];
        let attn: Vec<f32> = (0..n).map(|i| 0.01 * (i % 5) as f32).collect();
        for mode in [MergeMode::PiToMe, MergeMode::ToMe, MergeMode::ToFu,
                     MergeMode::DiffRate, MergeMode::Dct] {
            let seqs: Vec<BatchSeq> = mats.iter().enumerate()
                .map(|(i, (x, kf))| BatchSeq {
                    ctx: mk_ctx(x, kf, &sizes, &attn),
                    seed: i as u64,
                })
                .collect();
            let batched = merge_step_batch(mode, &seqs, 4);
            for (i, (x, kf)) in mats.iter().enumerate() {
                let mut rng = Rng::new(i as u64);
                let ctx = mk_ctx(x, kf, &sizes, &attn);
                let (want, want_sizes) = merge_step(mode, &ctx, &mut rng);
                let (got, got_sizes) = &batched[i];
                assert_eq!(got.rows, want.rows, "{mode:?} seq {i}");
                assert!(got.max_abs_diff(&want) < 1e-6, "{mode:?} seq {i}");
                assert_eq!(got_sizes, &want_sizes, "{mode:?} seq {i}");
            }
        }
    }

    #[test]
    fn frag_queue_serial_drain_covers_everything_in_order() {
        let mut items: Vec<u32> = (0..11).collect();
        let mut outs = vec![0u32; 11];
        let q = FragQueue::new(&mut items, &mut outs, 4);
        let mut seen = Vec::new();
        while let Some((base, fa, fb)) = q.pop() {
            assert_eq!(fa.len(), fb.len());
            for (off, (item, out)) in fa.iter().zip(fb.iter_mut()).enumerate() {
                assert_eq!(*item as usize, base + off, "fragment base indexes");
                *out = *item * 10;
                seen.push(base + off);
            }
        }
        // fragments of 4, 4, then the 3-item tail, in order, no overlap
        assert_eq!(seen, (0..11).collect::<Vec<_>>());
        assert_eq!(outs, (0..11).map(|v| v * 10).collect::<Vec<_>>());
    }

    #[test]
    fn frag_queue_fragment_sizing() {
        // frag larger than the batch hands everything out in one pop
        let mut items = vec![7u8; 3];
        let mut outs = vec![0u8; 3];
        let q = FragQueue::new(&mut items, &mut outs, 64);
        let (base, fa, _) = q.pop().expect("one fragment");
        assert_eq!((base, fa.len()), (0, 3));
        assert!(q.pop().is_none());

        // frag=0 clamps to 1 (one pair per pop)
        let mut items = vec![1u8, 2, 3];
        let mut outs = vec![0u8; 3];
        let q = FragQueue::new(&mut items, &mut outs, 0);
        let mut pops = 0;
        while let Some((_, fa, _)) = q.pop() {
            assert_eq!(fa.len(), 1);
            pops += 1;
        }
        assert_eq!(pops, 3);

        // empty slices drain immediately
        let mut items: Vec<u8> = Vec::new();
        let mut outs: Vec<u8> = Vec::new();
        let q = FragQueue::new(&mut items, &mut outs, 4);
        assert!(q.pop().is_none());
    }

    #[test]
    fn frag_queue_concurrent_drain_processes_each_item_once() {
        let n = 103;
        let mut items: Vec<usize> = (0..n).collect();
        let mut outs = vec![0usize; n];
        let q = FragQueue::new(&mut items, &mut outs, 3);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some((base, fa, fb)) = q.pop() {
                        for (off, (item, out)) in
                            fa.iter().zip(fb.iter_mut()).enumerate()
                        {
                            assert_eq!(*item, base + off);
                            *out += item + 1; // += catches double delivery
                        }
                    }
                });
            }
        });
        // every slot written exactly once, regardless of which worker won
        assert_eq!(outs, (1..=n).collect::<Vec<_>>());
    }

    #[test]
    fn stochastic_modes_are_seed_deterministic() {
        let n = 19;
        let x = rand_mat(n, 8, 1);
        let sizes = vec![1.0f32; n];
        let attn = vec![0.0f32; n];
        let mk_seq = |seed| BatchSeq {
            ctx: MergeCtx {
                x: &x, kf: &x, sizes: &sizes, attn_cls: &attn,
                margin: 0.45, k: 4, protect_first: 1,
                tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
            },
            seed,
        };
        let seqs: Vec<BatchSeq> = (0..4).map(mk_seq).collect();
        let a = merge_step_batch(MergeMode::Random, &seqs, 4);
        let seqs: Vec<BatchSeq> = (0..4).map(mk_seq).collect();
        let b = merge_step_batch(MergeMode::Random, &seqs, 2);
        for (ra, rb) in a.iter().zip(&b) {
            assert!(ra.0.max_abs_diff(&rb.0) < 1e-7);
            assert_eq!(ra.1, rb.1);
        }
    }
}
