//! DCT baseline (Fourier-transformer style, He et al. 2023): truncate the
//! token sequence in frequency space.  Mirrors `ref.dct_merge`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::tensor::Mat;

thread_local! {
    /// Per-thread DCT basis cache keyed by n.  The encoder calls
    /// [`dct_merge`] with the same (shrinking) token counts on every
    /// forward, so each worker thread pays the O(n²) trig build once per
    /// distinct n instead of once per call.
    static DCT_BASES: RefCell<HashMap<usize, Rc<Mat>>> =
        RefCell::new(HashMap::new());
}

/// Orthonormal DCT-II matrix D (n, n): `D @ x` computes the DCT along the
/// token axis.
pub fn dct_matrix(n: usize) -> Mat {
    let mut d = Mat::zeros(n, n);
    let nf = n as f64;
    for i in 0..n {
        let scale = if i == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
        for j in 0..n {
            let v = (std::f64::consts::PI / nf * (j as f64 + 0.5) * i as f64).cos();
            d.set(i, j, (v * scale) as f32);
        }
    }
    d
}

/// Thread-locally cached [`dct_matrix`]: the first call per (thread, n)
/// builds the basis, later calls share it.
// lint: allow(alloc) reason=Rc refcount clone of the cached DCT matrix
pub fn dct_matrix_cached(n: usize) -> Rc<Mat> {
    DCT_BASES.with(|c| {
        c.borrow_mut()
            .entry(n)
            .or_insert_with(|| Rc::new(dct_matrix(n)))
            .clone()
    })
}

/// DCT merge: keep the low-frequency band of the non-protected tokens and
/// resynthesize `n - protect_first - k` tokens on the coarse grid
/// (allocating wrapper over [`dct_merge_into`]).
/// Sizes reset to 1 (no tracking, as in the paper's DCT baseline).
// lint: allow(alloc) reason=allocating convenience wrapper over dct_merge_into
pub fn dct_merge(x: &Mat, sizes: &[f32], k: usize, protect_first: usize)
    -> (Mat, Vec<f32>) {
    let mut body = Mat::zeros(0, 0);
    let mut freq = Mat::zeros(0, 0);
    let mut out = Mat::zeros(0, 0);
    let mut out_sizes = Vec::new();
    dct_merge_into(x, sizes, k, protect_first, &mut body, &mut freq,
                   &mut out, &mut out_sizes);
    (out, out_sizes)
}

/// DCT merge into reusable buffers — allocation-free once `body`/`freq`/
/// `out` have seen their largest shapes and the thread-local basis cache
/// holds this `n` (the scratch-workspace form [`crate::merge::
/// merge_step_scratch`] runs on).
///
/// Numerics are identical to the historical allocating path: the
/// truncated analysis (`D[:keep] @ body`) and the resynthesis
/// (`D[:keep,:keep]^T @ freq`) use the same ikj, zero-skipping
/// accumulation order as `matmul_into`.
#[allow(clippy::too_many_arguments)]
pub fn dct_merge_into(x: &Mat, _sizes: &[f32], k: usize, protect_first: usize,
                      body: &mut Mat, freq: &mut Mat,
                      out: &mut Mat, out_sizes: &mut Vec<f32>) {
    let nb = x.rows - protect_first;
    let keep = nb - k;
    let d = dct_matrix_cached(nb);
    // body = x[protect_first..]
    body.reshape(nb, x.cols);
    for i in 0..nb {
        body.row_mut(i).copy_from_slice(x.row(protect_first + i));
    }
    // freq = D[:keep] @ body — only the kept low-frequency band is ever
    // read back, so the high-frequency rows are not computed at all
    freq.reset(keep, x.cols);
    for i in 0..keep {
        let arow = d.row(i);
        let crow = freq.row_mut(i);
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = body.row(kk);
            for (cj, &bv) in crow.iter_mut().zip(brow) {
                *cj += av * bv;
            }
        }
    }
    // out = [x[..protect_first] ; D[:keep,:keep]^T @ freq]
    let n_out = protect_first + keep;
    out.reshape(n_out, x.cols);
    for i in 0..protect_first {
        out.row_mut(i).copy_from_slice(x.row(i));
    }
    for i in 0..keep {
        let orow = out.row_mut(protect_first + i);
        orow.fill(0.0);
        for kk in 0..keep {
            let av = d.get(kk, i);
            if av == 0.0 {
                continue;
            }
            let brow = freq.row(kk);
            for (oj, &bv) in orow.iter_mut().zip(brow) {
                *oj += av * bv;
            }
        }
    }
    out_sizes.clear();
    out_sizes.resize(n_out, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::tensor::matmul_nt;

    #[test]
    fn dct_matrix_is_orthonormal() {
        let d = dct_matrix(16);
        let ddt = matmul_nt(&d, &d);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((ddt.get(i, j) - want).abs() < 1e-4,
                        "D D^T [{i},{j}] = {}", ddt.get(i, j));
            }
        }
    }

    #[test]
    fn full_band_reconstructs() {
        // k = 0 -> keep == nb, resynthesis is exact inverse
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(9, 4, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let (out, _) = dct_merge(&x, &vec![1.0; 9], 0, 1);
        assert!(out.max_abs_diff(&x) < 1e-4);
    }

    #[test]
    fn truncation_reduces_tokens() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(17, 4, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let (out, sizes) = dct_merge(&x, &vec![1.0; 17], 5, 1);
        assert_eq!(out.rows, 12);
        assert!(sizes.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn cached_basis_matches_uncached_and_is_shared() {
        for n in [1usize, 2, 7, 16, 33] {
            let cached = dct_matrix_cached(n);
            let direct = dct_matrix(n);
            assert_eq!(cached.rows, direct.rows, "n={n}");
            assert!(cached.max_abs_diff(&direct) == 0.0, "n={n}");
            // second lookup returns the same shared allocation
            let again = dct_matrix_cached(n);
            assert!(Rc::ptr_eq(&cached, &again), "n={n} rebuilt the basis");
        }
    }

    #[test]
    fn dct_merge_into_reuses_dirty_buffers_and_matches() {
        let mut rng = Rng::new(3);
        // dirty, wrongly-shaped buffers reused across shrinking and growing
        // shapes: the into-path must still match the wrapper bitwise
        let mut body = Mat::from_fn(5, 5, |_, _| 9.0);
        let mut freq = Mat::from_fn(2, 2, |_, _| 9.0);
        let mut out = Mat::from_fn(1, 1, |_, _| 9.0);
        let mut sizes = vec![5.0; 3];
        for (n, k) in [(17usize, 5usize), (9, 2), (17, 8)] {
            let x = Mat::from_fn(n, 4, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
            let (want, want_sizes) = dct_merge(&x, &vec![1.0; n], k, 1);
            dct_merge_into(&x, &vec![1.0; n], k, 1, &mut body, &mut freq,
                           &mut out, &mut sizes);
            assert!(out.max_abs_diff(&want) == 0.0, "n={n} k={k}");
            assert_eq!(sizes, want_sizes, "n={n} k={k}");
        }
    }

    #[test]
    fn preserves_constant_signal() {
        // A constant token sequence lives entirely in frequency 0: heavy
        // truncation must still reproduce (scaled) constant tokens.
        let x = Mat::from_fn(17, 3, |i, j| if i == 0 { 0.0 } else { (j + 1) as f32 });
        let (out, _) = dct_merge(&x, &vec![1.0; 17], 8, 1);
        // all body rows equal each other
        for i in 2..out.rows {
            for j in 0..3 {
                assert!((out.get(i, j) - out.get(1, j)).abs() < 1e-3);
            }
        }
    }
}
