//! DCT baseline (Fourier-transformer style, He et al. 2023): truncate the
//! token sequence in frequency space.  Mirrors `ref.dct_merge`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::tensor::{matmul, Mat};

thread_local! {
    /// Per-thread DCT basis cache keyed by n.  The encoder calls
    /// [`dct_merge`] with the same (shrinking) token counts on every
    /// forward, so each worker thread pays the O(n²) trig build once per
    /// distinct n instead of once per call.
    static DCT_BASES: RefCell<HashMap<usize, Rc<Mat>>> =
        RefCell::new(HashMap::new());
}

/// Orthonormal DCT-II matrix D (n, n): `D @ x` computes the DCT along the
/// token axis.
pub fn dct_matrix(n: usize) -> Mat {
    let mut d = Mat::zeros(n, n);
    let nf = n as f64;
    for i in 0..n {
        let scale = if i == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
        for j in 0..n {
            let v = (std::f64::consts::PI / nf * (j as f64 + 0.5) * i as f64).cos();
            d.set(i, j, (v * scale) as f32);
        }
    }
    d
}

/// Thread-locally cached [`dct_matrix`]: the first call per (thread, n)
/// builds the basis, later calls share it.
pub fn dct_matrix_cached(n: usize) -> Rc<Mat> {
    DCT_BASES.with(|c| {
        c.borrow_mut()
            .entry(n)
            .or_insert_with(|| Rc::new(dct_matrix(n)))
            .clone()
    })
}

/// DCT merge: keep the low-frequency band of the non-protected tokens and
/// resynthesize `n - protect_first - k` tokens on the coarse grid.
/// Sizes reset to 1 (no tracking, as in the paper's DCT baseline).
pub fn dct_merge(x: &Mat, _sizes: &[f32], k: usize, protect_first: usize)
    -> (Mat, Vec<f32>) {
    let nb = x.rows - protect_first;
    let keep = nb - k;
    let d = dct_matrix_cached(nb);
    // body = x[protect_first..]
    let body = Mat::from_fn(nb, x.cols, |i, j| x.get(protect_first + i, j));
    let freq = matmul(&d, &body);
    // trunc = freq[:keep]; out = D[:keep,:keep]^T @ trunc
    let trunc = Mat::from_fn(keep, x.cols, |i, j| freq.get(i, j));
    let dk = Mat::from_fn(keep, keep, |i, j| d.get(i, j));
    let body_out = matmul(&dk.transpose(), &trunc);
    let head = Mat::from_fn(protect_first, x.cols, |i, j| x.get(i, j));
    let out = head.vcat(&body_out);
    let sizes = vec![1.0; out.rows];
    (out, sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::tensor::matmul_nt;

    #[test]
    fn dct_matrix_is_orthonormal() {
        let d = dct_matrix(16);
        let ddt = matmul_nt(&d, &d);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((ddt.get(i, j) - want).abs() < 1e-4,
                        "D D^T [{i},{j}] = {}", ddt.get(i, j));
            }
        }
    }

    #[test]
    fn full_band_reconstructs() {
        // k = 0 -> keep == nb, resynthesis is exact inverse
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(9, 4, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let (out, _) = dct_merge(&x, &vec![1.0; 9], 0, 1);
        assert!(out.max_abs_diff(&x) < 1e-4);
    }

    #[test]
    fn truncation_reduces_tokens() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(17, 4, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let (out, sizes) = dct_merge(&x, &vec![1.0; 17], 5, 1);
        assert_eq!(out.rows, 12);
        assert!(sizes.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn cached_basis_matches_uncached_and_is_shared() {
        for n in [1usize, 2, 7, 16, 33] {
            let cached = dct_matrix_cached(n);
            let direct = dct_matrix(n);
            assert_eq!(cached.rows, direct.rows, "n={n}");
            assert!(cached.max_abs_diff(&direct) == 0.0, "n={n}");
            // second lookup returns the same shared allocation
            let again = dct_matrix_cached(n);
            assert!(Rc::ptr_eq(&cached, &again), "n={n} rebuilt the basis");
        }
    }

    #[test]
    fn preserves_constant_signal() {
        // A constant token sequence lives entirely in frequency 0: heavy
        // truncation must still reproduce (scaled) constant tokens.
        let x = Mat::from_fn(17, 3, |i, j| if i == 0 { 0.0 } else { (j + 1) as f32 });
        let (out, _) = dct_merge(&x, &vec![1.0; 17], 8, 1);
        // all body rows equal each other
        for i in 2..out.rows {
            for j in 0..3 {
                assert!((out.get(i, j) - out.get(1, j)).abs() < 1e-3);
            }
        }
    }
}
