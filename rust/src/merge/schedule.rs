//! Merge schedules: ratio-r (the paper's choice) vs fixed-k (ToMe's
//! original), mirrored from `python/compile/common.py` so both languages
//! produce identical static token plans.

/// Number of tokens after one ratio-r merge step; `protect_first` tokens
/// (CLS) are never candidates.  Degenerate inputs (`n < protect_first`,
/// or fewer than two merge candidates) return `n` unchanged — the old
/// `n - protect_first` underflowed (debug panic, release wraparound)
/// when every token was protected.
pub fn tokens_after_merge(n: usize, r: f64, protect_first: usize) -> usize {
    let n_c = n.saturating_sub(protect_first);
    if n_c < 2 {
        return n;
    }
    let k = n_c as i64 - (n_c as f64 * r).floor() as i64;
    let k = k.max(0).min(n_c as i64 / 2).min(n_c as i64 - 2).max(0) as usize;
    n - k
}

/// Static token-count plan: entry l = tokens entering block l, plus a final
/// entry for the output count. `merge_layers` restricts merging to specific
/// blocks (BERT compresses only the first 3, Sec 4.4).
// lint: allow(alloc) reason=per-run schedule built once at configuration time
pub fn merge_plan(n0: usize, r: f64, num_layers: usize, protect_first: usize,
                  merge_layers: Option<&[usize]>) -> Vec<usize> {
    let mut plan = vec![n0];
    let mut n = n0;
    for l in 0..num_layers {
        let active = merge_layers.map_or(true, |ls| ls.contains(&l));
        if active {
            n = tokens_after_merge(n, r, protect_first);
        }
        plan.push(n);
    }
    plan
}

/// ToMe's original schedule: remove a fixed k tokens per layer (App. C).
// lint: allow(alloc) reason=per-run schedule built once at configuration time
pub fn fixed_k_plan(n0: usize, k: usize, num_layers: usize,
                    protect_first: usize) -> Vec<usize> {
    let mut plan = vec![n0];
    let mut n = n0;
    for _ in 0..num_layers {
        let n_c = n as i64 - protect_first as i64;
        let kk = (k as i64).min((n_c - 2) / 2).max(0) as usize;
        n -= kk;
        plan.push(n);
    }
    plan
}

/// Total tokens removed by a plan.
pub fn total_removed(plan: &[usize]) -> usize {
    plan.first().copied().unwrap_or(0) - plan.last().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_python_reference_values() {
        // cross-checked against compile.common.merge_plan(65, 0.9, 4)
        let plan = merge_plan(65, 0.9, 4, 1, None);
        assert_eq!(plan[0], 65);
        assert!(plan.windows(2).all(|w| w[1] <= w[0]));
        assert!(plan.last().unwrap() >= &3);
    }

    #[test]
    fn ratio_removes_more_early() {
        let plan = merge_plan(197, 0.9, 12, 1, None);
        let early = plan[0] - plan[1];
        let late = plan[11] - plan[12];
        assert!(early >= late, "{plan:?}");
    }

    #[test]
    fn fixed_k_is_linear_until_floor() {
        let plan = fixed_k_plan(197, 8, 12, 1);
        for w in plan.windows(2).take(10) {
            assert_eq!(w[0] - w[1], 8);
        }
    }

    #[test]
    fn merge_layers_restriction() {
        let plan = merge_plan(129, 0.8, 6, 1, Some(&[0, 1, 2]));
        assert_eq!(plan[3], plan[4]);
        assert_eq!(plan[4], plan[5]);
        assert!(plan[3] < plan[0]);
    }

    #[test]
    fn never_below_two_candidates() {
        let plan = merge_plan(10, 0.5, 30, 1, None);
        assert!(*plan.last().unwrap() >= 3);
    }

    /// Degenerate (n, protect_first) pairs must never underflow: when
    /// everything is protected (or fewer than two candidates remain) the
    /// count passes through unchanged.
    #[test]
    fn degenerate_protect_first_never_underflows() {
        let pairs = [(0usize, 0usize), (0, 1), (1, 1), (1, 5), (2, 3),
                     (3, 4), (2, 1), (3, 1), (2, 0), (1, 0)];
        for &(n, pf) in &pairs {
            for &r in &[0.0, 0.5, 0.9, 1.0] {
                let out = tokens_after_merge(n, r, pf);
                assert!(out <= n, "grew: n={n} pf={pf} r={r} -> {out}");
                if n <= pf + 1 {
                    assert_eq!(out, n,
                               "degenerate n={n} pf={pf} must pass through");
                }
            }
        }
        // a fully-degenerate plan stays flat instead of panicking
        let plan = merge_plan(2, 0.5, 4, 3, None);
        assert!(plan.iter().all(|&x| x == 2), "{plan:?}");
    }
}
