//! Merge-plan representation and application.
//!
//! Contract (identical to `ref.py`'s mm formulation): output layout is
//! `[protected tokens..., B tokens...]`; every A token merges into
//! `b[dst[a]]` with weight `sizes[a]` when `gate[a] == 1`, and is dropped
//! (pruned) when `gate[a] == 0`.
//!
//! # The in-place plan lifecycle
//!
//! A [`MergePlan`] is five index/gate vectors; at serving steady state it
//! is **rebuilt in place** every merge step rather than reallocated:
//!
//! 1. The builder ([`crate::merge::pitome::ordered_bsm_plan_gram_into`]
//!    and friends) starts with [`MergePlan::clear`], which empties all
//!    five vectors but keeps their capacity.
//! 2. It fills them back up through `extend`/`push`/`resize`, using a
//!    [`PlanScratch`] for its intermediate orderings — once both have seen
//!    their largest shape, a rebuild performs zero heap allocations
//!    (asserted by `tests/alloc_free.rs`).
//! 3. [`apply_plan_into`] consumes the plan against reusable output
//!    buffers; the caller `mem::swap`s those with its live token state
//!    (see [`MergeScratch`](crate::merge::MergeScratch)).
//!
//! The allocating builders ([`apply_plan`], `ordered_bsm_plan_gram`, ...)
//! survive as thin wrappers that run the same in-place code against fresh
//! buffers, so one-shot callers and tests are unchanged.  `validate` is
//! deliberately allocation-free on its success path: it runs inside
//! `debug_assert!`s on the zero-allocation hot path.

use crate::tensor::Mat;

/// A fully-resolved merge plan over n tokens.
#[derive(Clone, Debug)]
pub struct MergePlan {
    /// indices kept as-is (ascending; CLS first)
    pub protect: Vec<usize>,
    /// source tokens (merged away or pruned)
    pub a: Vec<usize>,
    /// destination candidate set B
    pub b: Vec<usize>,
    /// for each a, position in `b` it merges into
    pub dst: Vec<usize>,
    /// 1.0 = merge, 0.0 = prune
    pub gate: Vec<f32>,
}

impl MergePlan {
    /// An empty plan to rebuild into (the start of the in-place
    /// lifecycle; see the module docs).
    // lint: allow(alloc) reason=zero-capacity Vecs, no heap allocation occurs
    pub fn empty() -> MergePlan {
        MergePlan {
            protect: Vec::new(),
            a: Vec::new(),
            b: Vec::new(),
            dst: Vec::new(),
            gate: Vec::new(),
        }
    }

    /// Reset to the empty plan without releasing buffer capacity — the
    /// first step of every `*_plan_gram_into` builder.
    pub fn clear(&mut self) {
        self.protect.clear();
        self.a.clear();
        self.b.clear();
        self.dst.clear();
        self.gate.clear();
    }

    /// Output token count.
    pub fn n_out(&self) -> usize {
        self.protect.len() + self.b.len()
    }

    /// Sanity-check invariants (used by tests and debug assertions).
    ///
    /// Allocation-free on the success path (it runs inside the
    /// `debug_assert!` of [`apply_plan_into`], which the zero-allocation
    /// tests measure in debug builds): duplicate detection is an O(m²)
    /// scan over the chained index lists instead of a seen-bitmap — m is
    /// a few hundred at most, and the scan only exists off the release
    /// hot path.
    // lint: allow(alloc) reason=error-path format! only, off the release hot path
    pub fn validate(&self, n: usize) -> Result<(), String> {
        let all = || self.protect.iter().chain(&self.a).chain(&self.b);
        for (pos, &i) in all().enumerate() {
            if i >= n {
                return Err(format!("index {i} out of range {n}"));
            }
            if all().take(pos).any(|&j| j == i) {
                return Err(format!("index {i} appears twice in plan"));
            }
        }
        if self.a.len() != self.dst.len() || self.a.len() != self.gate.len() {
            return Err("a/dst/gate length mismatch".into());
        }
        for (i, &d) in self.dst.iter().enumerate() {
            // out-of-range dst is always invalid when B is non-empty; with
            // an empty B it is invalid exactly when the gate would merge
            // (a gate-0 entry never reads its dst — pruning into an empty
            // B is legal)
            if d >= self.b.len() && (!self.b.is_empty() || self.gate[i] != 0.0) {
                return Err(format!(
                    "dst {d} out of B range {} (gate {})", self.b.len(),
                    self.gate[i]));
            }
        }
        Ok(())
    }
}

/// Reusable intermediate buffers for the allocation-free plan builders
/// (`*_plan_gram_into`): the mutable ranking-signal copy, argsort
/// orderings, the pre-filter A-side candidate list, and per-pair
/// best-match scores.  One instance lives inside every
/// [`MergeScratch`](crate::merge::MergeScratch); buffers grow to the
/// largest shape they see and are then reused without allocating.
pub struct PlanScratch {
    /// mutable copy of the ranking signal (protected prefix sunk/raised)
    pub(crate) scores_tmp: Vec<f32>,
    /// argsort output over `scores_tmp`
    pub(crate) order: Vec<usize>,
    /// candidate indices entering the matching (PiToMe's shuffled
    /// candidate list / the random baseline's permutation)
    pub(crate) merge_idx: Vec<usize>,
    /// A-side candidate tokens before the top-k pair filter
    pub(crate) a_all: Vec<usize>,
    /// best-match similarity per A candidate
    pub(crate) best: Vec<f32>,
    /// best-match B position per A candidate
    pub(crate) dst_all: Vec<usize>,
    /// argsort output over `best` (pair ranking)
    pub(crate) pair_rank: Vec<usize>,
}

impl PlanScratch {
    /// Empty scratch; buffers grow on first use and are then reused.
    // lint: allow(alloc) reason=cold constructor: scratch buffers grow on first use
    pub fn new() -> PlanScratch {
        PlanScratch {
            scores_tmp: Vec::new(),
            order: Vec::new(),
            merge_idx: Vec::new(),
            a_all: Vec::new(),
            best: Vec::new(),
            dst_all: Vec::new(),
            pair_rank: Vec::new(),
        }
    }
}

impl Default for PlanScratch {
    fn default() -> Self {
        PlanScratch::new()
    }
}

/// Apply a merge plan: size-weighted averaging with size tracking
/// (allocating wrapper over [`apply_plan_into`]).
// lint: allow(alloc) reason=allocating convenience wrapper over apply_plan_into
pub fn apply_plan(x: &Mat, sizes: &[f32], plan: &MergePlan) -> (Mat, Vec<f32>) {
    let mut out = Mat::zeros(0, 0);
    let mut out_sizes = Vec::new();
    apply_plan_into(x, sizes, plan, &mut out, &mut out_sizes);
    (out, out_sizes)
}

/// Apply a merge plan into reusable output buffers — the scratch-workspace
/// forward pass calls this every merge step without allocating once the
/// buffers have seen their largest shape.
pub fn apply_plan_into(x: &Mat, sizes: &[f32], plan: &MergePlan,
                       out: &mut Mat, out_sizes: &mut Vec<f32>) {
    debug_assert!(plan.validate(x.rows).is_ok(), "{:?}", plan.validate(x.rows));
    let h = x.cols;
    let n_out = plan.n_out();
    out.reshape(n_out, h);
    out_sizes.clear();
    out_sizes.resize(n_out, 0f32);

    // protected tokens pass through unchanged
    for (oi, &si) in plan.protect.iter().enumerate() {
        out.row_mut(oi).copy_from_slice(x.row(si));
        out_sizes[oi] = sizes[si];
    }
    let off = plan.protect.len();
    // B receives its own mass
    for (bi, &si) in plan.b.iter().enumerate() {
        let m = sizes[si];
        let r = out.row_mut(off + bi);
        let src = x.row(si);
        for k in 0..h {
            r[k] = src[k] * m;
        }
        out_sizes[off + bi] = m;
    }
    // A contributes gated mass to its destination
    for (ai, &si) in plan.a.iter().enumerate() {
        let g = plan.gate[ai];
        if g == 0.0 {
            continue;
        }
        let m = sizes[si] * g;
        let d = off + plan.dst[ai];
        let src = x.row(si);
        // split borrows: copy row then add
        for k in 0..h {
            out.data[d * h + k] += src[k] * m;
        }
        out_sizes[d] += m;
    }
    // normalize merged rows back to averages
    for bi in 0..plan.b.len() {
        let m = out_sizes[off + bi].max(1e-9);
        let r = out.row_mut(off + bi);
        for v in r.iter_mut() {
            *v /= m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_plan_passthrough() {
        let x = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f32);
        let plan = MergePlan {
            protect: vec![0, 1, 2, 3],
            a: vec![],
            b: vec![],
            dst: vec![],
            gate: vec![],
        };
        let (out, sizes) = apply_plan(&x, &[1.0; 4], &plan);
        assert_eq!(out, x);
        assert_eq!(sizes, vec![1.0; 4]);
    }

    #[test]
    fn two_token_merge_is_weighted_average() {
        let x = Mat::from_vec(3, 1, vec![0.0, 2.0, 10.0]);
        let plan = MergePlan {
            protect: vec![0],
            a: vec![2],
            b: vec![1],
            dst: vec![0],
            gate: vec![1.0],
        };
        let (out, sizes) = apply_plan(&x, &[1.0, 3.0, 1.0], &plan);
        // merged = (2*3 + 10*1) / 4 = 4
        assert_eq!(out.get(1, 0), 4.0);
        assert_eq!(sizes, vec![1.0, 4.0]);
    }

    #[test]
    fn pruned_token_vanishes() {
        let x = Mat::from_vec(3, 1, vec![0.0, 2.0, 10.0]);
        let plan = MergePlan {
            protect: vec![0],
            a: vec![2],
            b: vec![1],
            dst: vec![0],
            gate: vec![0.0],
        };
        let (out, sizes) = apply_plan(&x, &[1.0, 3.0, 1.0], &plan);
        assert_eq!(out.get(1, 0), 2.0);
        assert_eq!(sizes, vec![1.0, 3.0]);
    }

    #[test]
    fn validate_rejects_merge_into_empty_b() {
        // regression: `d >= b.len() && !b.is_empty()` short-circuited, so
        // with an empty B *any* dst passed validation even though applying
        // the plan would index out of bounds for every merging entry
        let plan = MergePlan {
            protect: vec![0],
            a: vec![1],
            b: vec![],
            dst: vec![0],
            gate: vec![1.0],
        };
        assert!(plan.validate(2).is_err(),
                "nonzero-gate entry with empty B must fail validation");
        // pruning (gate 0) into an empty B never reads dst and stays legal
        let prune = MergePlan { gate: vec![0.0], ..plan };
        assert!(prune.validate(2).is_ok());
        let (out, sizes) = apply_plan(&Mat::from_vec(2, 1, vec![3.0, 5.0]),
                                      &[1.0, 1.0], &prune);
        assert_eq!(out.rows, 1);
        assert_eq!(sizes, vec![1.0]);
    }

    #[test]
    fn apply_plan_into_reuses_buffers_and_matches() {
        let x = Mat::from_fn(6, 3, |i, j| (i * 3 + j) as f32 * 0.5);
        let sizes = [1.0, 2.0, 1.0, 3.0, 1.0, 1.0];
        let plan = MergePlan {
            protect: vec![0],
            a: vec![4, 5],
            b: vec![1, 2, 3],
            dst: vec![0, 2],
            gate: vec![1.0, 0.0],
        };
        let (want, want_sizes) = apply_plan(&x, &sizes, &plan);
        // dirty, over-sized buffers: into-path must still match exactly
        let mut out = Mat::from_fn(9, 9, |_, _| 42.0);
        let mut out_sizes = vec![9.0; 17];
        apply_plan_into(&x, &sizes, &plan, &mut out, &mut out_sizes);
        assert_eq!(out, want);
        assert_eq!(out_sizes, want_sizes);
    }

    #[test]
    fn clear_empties_without_releasing_capacity() {
        let mut plan = MergePlan {
            protect: vec![0, 1],
            a: vec![2],
            b: vec![3],
            dst: vec![0],
            gate: vec![1.0],
        };
        plan.validate(4).unwrap();
        let cap = plan.protect.capacity();
        plan.clear();
        assert_eq!(plan.n_out(), 0);
        assert!(plan.a.is_empty() && plan.b.is_empty() && plan.dst.is_empty());
        assert!(plan.protect.capacity() >= cap, "clear must keep capacity");
        plan.validate(0).unwrap();
        assert_eq!(MergePlan::empty().n_out(), 0);
    }

    #[test]
    fn validate_catches_duplicates() {
        let plan = MergePlan {
            protect: vec![0, 1],
            a: vec![1],
            b: vec![2],
            dst: vec![0],
            gate: vec![1.0],
        };
        assert!(plan.validate(3).is_err());
    }
}
