//! Pure-Rust merge engine: PiToMe (Alg. 1) + every compared baseline.
//!
//! Semantics mirror `python/compile/kernels/ref.py` (the *mm* plan
//! contract): a plan is `(protect, a, b, dst, gate)` with output layout
//! `[protected..., B...]`, every A token merging into `b[dst]` when its
//! gate is 1.0 and being pruned when 0.0.  Cross-language parity is
//! asserted against `artifacts/testvectors.json`.
//!
//! # The shared-Gram pipeline
//!
//! All similarity-driven modes are built around **one**
//! [`CosineGram`](crate::tensor::CosineGram) per merge step:
//! [`merge_step`] normalizes the key features and computes the blocked
//! symmetric cosine Gram exactly once, then feeds it to *both* the energy
//! score ([`energy::energy_from_gram`], Eq. 4) and the plan builder
//! ([`pitome::ordered_bsm_plan_gram`], [`tome::tome_plan_gram`],
//! [`diffrate::diffrate_plan_gram`]).  The pre-refactor pipeline paid for
//! the O(n²h) Gram twice — once inside `energy_scores` and again inside
//! the plan builder's A×B dot products — which is why this is the benched
//! hot path (`cargo bench --bench merge_bench`).  The feature-taking
//! functions (`energy_scores`, `ordered_bsm_plan`, ...) survive as thin
//! wrappers that build their own Gram, so external callers are unchanged.
//!
//! # Scratch-backed merging
//!
//! [`merge_step_scratch`] is the allocation-free form the encoder's
//! scratch workspace (`model::encoder::EncoderScratch`) runs on: the
//! shared Gram is rebuilt in place, the plan is rebuilt into a reusable
//! [`MergePlan`] by the `*_plan_gram_into` builders (intermediate
//! orderings live in a [`PlanScratch`]; see the in-place lifecycle in
//! [`plan`]), and the plan is applied via [`apply_plan_into`] — all with
//! the same one-Gram-per-step invariant and **zero** steady-state heap
//! allocations across every mode, DCT and random pruning included
//! (asserted by `tests/alloc_free.rs`).
//!
//! # Batched merging
//!
//! [`batch::merge_step_batch`] runs merge steps for a whole batch of
//! sequences across scoped worker threads (each sequence still builds
//! exactly one Gram, on whichever thread processes it).  The batch
//! encoder fans out whole samples instead (one scratch per worker —
//! `batch::parallel_map_mut_ctx`); `merge_step_batch` remains for
//! merge-only workloads and the benches.

pub mod batch;
pub mod dct;
pub mod diffrate;
pub mod energy;
pub mod pitome;
pub mod plan;
pub mod random;
pub mod schedule;
pub mod tome;
pub mod unmerge;

pub use batch::{merge_step_batch, BatchSeq};
pub use energy::{energy_from_gram, energy_from_gram_into, energy_scores};
pub use plan::{apply_plan, apply_plan_into, MergePlan, PlanScratch};
pub use schedule::{fixed_k_plan, merge_plan, tokens_after_merge};
pub use unmerge::{unmerge, MergeTracker};

use crate::data::Rng;
use crate::obs::merge_stats::{energy_summary, MergeLayerStats,
                              MergeTelemetry};
use crate::obs::ring::{RingWriter, SpanEvent};
use crate::obs::stages::Stage;
use crate::tensor::{CosineGram, Mat};

/// Which merge algorithm to run in a block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MergeMode {
    /// no merging
    None,
    /// PiToMe: energy-protected ordered BSM (the paper's method)
    PiToMe,
    /// PiToMe ablation: no protection step (Table 1 row 1)
    PiToMeNoProtect,
    /// PiToMe ablation: random A/B split (Table 1 row 2)
    PiToMeRandomSplit,
    /// PiToMe ablation: CLS-attention indicator instead of energy (Fig. 4)
    PiToMeAttn,
    /// ToMe parity-split BSM
    ToMe,
    /// ToFu: ToMe matching with prune-below-threshold
    ToFu,
    /// DCT frequency-truncation baseline
    Dct,
    /// DiffRate-style attention-ranked merging (fixed schedule)
    DiffRate,
    /// random pruning baseline
    Random,
}

impl MergeMode {
    /// Parse from CLI/manifest strings (same names as python).
    pub fn parse(s: &str) -> Option<MergeMode> {
        Some(match s {
            "none" => MergeMode::None,
            "pitome" => MergeMode::PiToMe,
            "pitome_noprot" => MergeMode::PiToMeNoProtect,
            "pitome_rand" => MergeMode::PiToMeRandomSplit,
            "pitome_attn" => MergeMode::PiToMeAttn,
            "tome" => MergeMode::ToMe,
            "tofu" => MergeMode::ToFu,
            "dct" => MergeMode::Dct,
            "diffrate" => MergeMode::DiffRate,
            "random" => MergeMode::Random,
            _ => return None,
        })
    }

    /// Canonical lowercase name.
    pub fn name(&self) -> &'static str {
        match self {
            MergeMode::None => "none",
            MergeMode::PiToMe => "pitome",
            MergeMode::PiToMeNoProtect => "pitome_noprot",
            MergeMode::PiToMeRandomSplit => "pitome_rand",
            MergeMode::PiToMeAttn => "pitome_attn",
            MergeMode::ToMe => "tome",
            MergeMode::ToFu => "tofu",
            MergeMode::Dct => "dct",
            MergeMode::DiffRate => "diffrate",
            MergeMode::Random => "random",
        }
    }

    /// All modes compared in the paper's figures.
    pub fn all() -> &'static [MergeMode] {
        &[
            MergeMode::PiToMe,
            MergeMode::ToMe,
            MergeMode::ToFu,
            MergeMode::Dct,
            MergeMode::DiffRate,
        ]
    }

    /// Whether this mode tracks token sizes (=> proportional attention).
    pub fn tracks_sizes(&self) -> bool {
        !matches!(self, MergeMode::None | MergeMode::Dct | MergeMode::Random)
    }
}

/// Context handed to one merge step.
pub struct MergeCtx<'a> {
    /// token features to merge, (n, h)
    pub x: &'a Mat,
    /// key features used for similarity, (n, h)
    pub kf: &'a Mat,
    /// token sizes, len n
    pub sizes: &'a [f32],
    /// mean CLS attention scores, len n (for attention-ranked modes)
    pub attn_cls: &'a [f32],
    /// energy margin for this layer (Eq. 4)
    pub margin: f32,
    /// number of tokens to merge away
    pub k: usize,
    /// leading protected tokens (CLS)
    pub protect_first: usize,
    /// ToFu prune threshold (see `config::DEFAULT_TOFU_PRUNE_THRESHOLD`)
    pub tofu_threshold: f32,
}

/// Run one merge step, returning (merged tokens, new sizes).
///
/// Similarity-driven modes build exactly one [`CosineGram`] here and share
/// it between scoring and matching; DCT and random pruning never touch
/// pairwise similarities and build none.
// lint: allow(alloc) reason=k==0 early-out copies input through the allocating wrapper API
pub fn merge_step(mode: MergeMode, ctx: &MergeCtx, rng: &mut Rng) -> (Mat, Vec<f32>) {
    if ctx.k == 0 || mode == MergeMode::None {
        return (ctx.x.clone(), ctx.sizes.to_vec());
    }
    match mode {
        MergeMode::None => unreachable!(),
        MergeMode::Dct => dct::dct_merge(ctx.x, ctx.sizes, ctx.k, ctx.protect_first),
        MergeMode::Random => {
            let plan = random::random_plan(ctx.x.rows, ctx.k, ctx.protect_first, rng);
            apply_plan(ctx.x, ctx.sizes, &plan)
        }
        _ => {
            let g = CosineGram::build(ctx.kf);
            merge_step_with_gram(mode, ctx, &g, rng)
        }
    }
}

/// Build the merge plan for a similarity-driven mode from the shared Gram
/// (allocating wrapper over [`plan_with_gram_into`]).
// lint: allow(alloc) reason=allocating convenience wrapper; hot callers use merge_step_scratch
fn plan_with_gram(mode: MergeMode, ctx: &MergeCtx, g: &CosineGram,
                  rng: &mut Rng) -> MergePlan {
    let mut energy = Vec::new();
    let mut bufs = PlanScratch::new();
    let mut plan = MergePlan::empty();
    plan_with_gram_into(mode, ctx, g, rng, &mut energy, &mut bufs, &mut plan);
    plan
}

/// Build the merge plan for a similarity-driven mode from the shared Gram
/// into reusable buffers (the single place the per-mode plan builders are
/// dispatched, so the allocating and scratch-backed paths cannot drift
/// apart).  `energy` holds the ranking signal (energy scores or negated
/// CLS attention); all paths are allocation-free once the buffers are
/// warm.
fn plan_with_gram_into(mode: MergeMode, ctx: &MergeCtx, g: &CosineGram,
                       rng: &mut Rng, energy: &mut Vec<f32>,
                       bufs: &mut PlanScratch, out: &mut MergePlan) {
    match mode {
        MergeMode::None | MergeMode::Dct | MergeMode::Random => {
            unreachable!("{mode:?} is not similarity-driven")
        }
        MergeMode::PiToMe => {
            energy_from_gram_into(g, ctx.margin, energy);
            pitome::ordered_bsm_plan_gram_into(
                g, energy, ctx.k, ctx.protect_first, pitome::Split::Alternate,
                true, rng, bufs, out)
        }
        MergeMode::PiToMeNoProtect => {
            energy_from_gram_into(g, ctx.margin, energy);
            pitome::ordered_bsm_plan_gram_into(
                g, energy, ctx.k, ctx.protect_first, pitome::Split::Alternate,
                false, rng, bufs, out)
        }
        MergeMode::PiToMeRandomSplit => {
            energy_from_gram_into(g, ctx.margin, energy);
            pitome::ordered_bsm_plan_gram_into(
                g, energy, ctx.k, ctx.protect_first, pitome::Split::Random,
                true, rng, bufs, out)
        }
        MergeMode::PiToMeAttn => {
            energy.clear();
            energy.extend(ctx.attn_cls.iter().map(|v| -v));
            pitome::ordered_bsm_plan_gram_into(
                g, energy, ctx.k, ctx.protect_first, pitome::Split::Alternate,
                true, rng, bufs, out)
        }
        MergeMode::ToMe => tome::tome_plan_gram_into(
            g, ctx.k, ctx.protect_first, None, bufs, out),
        MergeMode::ToFu => tome::tome_plan_gram_into(
            g, ctx.k, ctx.protect_first, Some(ctx.tofu_threshold), bufs, out),
        MergeMode::DiffRate => diffrate::diffrate_plan_gram_into(
            g, ctx.attn_cls, ctx.k, ctx.protect_first, bufs, out),
    }
}

/// Run one merge step against a caller-provided shared Gram (must have
/// been built from `ctx.kf`).  Gram-free modes (None/DCT/Random) fall
/// through to the plain path and ignore `g`.
// lint: allow(alloc) reason=allocating wrapper; k==0 path copies input
pub fn merge_step_with_gram(mode: MergeMode, ctx: &MergeCtx, g: &CosineGram,
                            rng: &mut Rng) -> (Mat, Vec<f32>) {
    debug_assert_eq!(g.n(), ctx.kf.rows, "Gram/feature shape mismatch");
    if ctx.k == 0 {
        return (ctx.x.clone(), ctx.sizes.to_vec());
    }
    match mode {
        MergeMode::None | MergeMode::Dct | MergeMode::Random => {
            merge_step(mode, ctx, rng)
        }
        _ => {
            let plan = plan_with_gram(mode, ctx, g, rng);
            apply_plan(ctx.x, ctx.sizes, &plan)
        }
    }
}

/// Reusable buffers for [`merge_step_scratch`]: the shared Gram, its
/// normalized-feature scratch, the ranking-signal and plan-builder
/// buffers, the in-place [`MergePlan`], the DCT baseline's scratch, and
/// the merged-token outputs.  Owned by an
/// [`EncoderScratch`](crate::model::EncoderScratch) (one per worker
/// thread); callers `mem::swap` the outputs with their live token state
/// after each step, so the buffers ping-pong and a warmed scratch makes
/// the whole merge step — scoring, plan construction, and application —
/// perform **zero** heap allocations (asserted by `tests/alloc_free.rs`).
pub struct MergeScratch {
    /// the per-step shared Gram, rebuilt in place
    gram: CosineGram,
    /// normalized-feature scratch for the Gram rebuild
    kn: Mat,
    /// ranking signal (energy scores / negated CLS attention)
    energy: Vec<f32>,
    /// plan-builder index and score buffers
    plan_bufs: PlanScratch,
    /// the in-place merge plan, rebuilt every step
    plan: MergePlan,
    /// DCT baseline: de-protected token block
    dct_body: Mat,
    /// DCT baseline: kept low-frequency band
    dct_freq: Mat,
    /// merged tokens (valid after a [`merge_step_scratch`] call)
    pub out_x: Mat,
    /// merged sizes (valid after a [`merge_step_scratch`] call)
    pub out_sizes: Vec<f32>,
    /// per-layer merge telemetry sink (disabled — zero capacity — by
    /// default; the encoder stamps the layer index before each step)
    pub telemetry: MergeTelemetry,
    /// span recorder for per-layer gram/plan/apply timings (None by
    /// default; attached by the owning worker at boot, primary lane only
    /// — see the single-producer contract in [`crate::obs::ring`])
    pub recorder: Option<RingWriter>,
}

impl MergeScratch {
    /// Empty scratch; buffers grow on first use and are then reused.
    // lint: allow(alloc) reason=cold constructor: scratch buffers grow on first use
    pub fn new() -> MergeScratch {
        MergeScratch {
            gram: CosineGram::empty(),
            kn: Mat::zeros(0, 0),
            energy: Vec::new(),
            plan_bufs: PlanScratch::new(),
            plan: MergePlan::empty(),
            dct_body: Mat::zeros(0, 0),
            dct_freq: Mat::zeros(0, 0),
            out_x: Mat::zeros(0, 0),
            out_sizes: Vec::new(),
            telemetry: MergeTelemetry::default(),
            recorder: None,
        }
    }

    /// Record the apply span + telemetry row for one finished merge step
    /// (no-op unless telemetry or a recorder is live; allocation-free —
    /// the span ring and the telemetry buffer are both fixed-capacity).
    fn note_apply(&mut self, li: u64, ctx: &MergeCtx, e_mean: f32,
                  e_max: f32, e_p90: f32, t_start: Option<u64>,
                  observed: bool) {
        if !observed {
            return;
        }
        let before = ctx.x.rows as u32;
        let after = self.out_x.rows as u32;
        if let Some(r) = self.recorder.as_ref() {
            r.record(SpanEvent {
                stage: Stage::LayerApply,
                id: li,
                t_start_us: t_start.unwrap_or(0),
                t_end_us: r.now_us(),
                payload: (before.min(0xFFFF) << 16) | after.min(0xFFFF),
                a: e_mean,
                b: e_p90,
            });
        }
        self.telemetry.push(MergeLayerStats {
            layer: 0, // stamped from the telemetry's current layer
            tokens_before: before,
            tokens_after: after,
            protected: ctx.protect_first as u32,
            energy_mean: e_mean,
            energy_max: e_max,
            energy_p90: e_p90,
        });
    }
}

impl Default for MergeScratch {
    fn default() -> Self {
        MergeScratch::new()
    }
}

/// Run one merge step into reusable scratch buffers, leaving the merged
/// tokens in `s.out_x` / `s.out_sizes`.
///
/// Numerics are identical to [`merge_step`] (both dispatch the same plan
/// builders and the same apply kernel).  Similarity-driven modes rebuild
/// `s.gram` in place (still exactly one Gram per step), build the plan
/// into `s.plan` via the `*_plan_gram_into` builders, and apply it via
/// [`apply_plan_into`]; DCT resynthesizes through its own scratch tiles;
/// `k == 0` / `None` copies the input through.  Every path performs zero
/// heap allocations once the scratch is warm — including when the
/// embedded [`MergeTelemetry`] sink and span recorder are live (both are
/// fixed-capacity; `tests/alloc_free.rs` runs warmed cycles with tracing
/// enabled).
///
/// When `s.telemetry` is enabled or `s.recorder` is attached, the
/// similarity-driven modes also summarize the step's ranking signal
/// (the Eq.-4 energy scores; negated CLS attention for
/// [`MergeMode::PiToMeAttn`]) into one [`MergeLayerStats`] row and three
/// spans ([`Stage::LayerGram`]/[`Stage::LayerPlan`]/[`Stage::LayerApply`]);
/// the similarity-free baselines record the apply span and a row with
/// zero energies.
pub fn merge_step_scratch(mode: MergeMode, ctx: &MergeCtx, rng: &mut Rng,
                          s: &mut MergeScratch) {
    if ctx.k == 0 || mode == MergeMode::None {
        s.out_x.copy_from(ctx.x);
        s.out_sizes.clear();
        s.out_sizes.extend_from_slice(ctx.sizes);
        return;
    }
    let observed = s.telemetry.enabled() || s.recorder.is_some();
    let li = s.telemetry.layer() as u64;
    match mode {
        MergeMode::None => unreachable!(),
        MergeMode::Dct => {
            let t0 = s.recorder.as_ref().map(|r| r.now_us());
            dct::dct_merge_into(ctx.x, ctx.sizes, ctx.k, ctx.protect_first,
                                &mut s.dct_body, &mut s.dct_freq,
                                &mut s.out_x, &mut s.out_sizes);
            s.note_apply(li, ctx, 0.0, 0.0, 0.0, t0, observed);
        }
        MergeMode::Random => {
            let t0 = s.recorder.as_ref().map(|r| r.now_us());
            random::random_plan_into(ctx.x.rows, ctx.k, ctx.protect_first,
                                     rng, &mut s.plan_bufs, &mut s.plan);
            apply_plan_into(ctx.x, ctx.sizes, &s.plan, &mut s.out_x,
                            &mut s.out_sizes);
            s.note_apply(li, ctx, 0.0, 0.0, 0.0, t0, observed);
        }
        _ => {
            let t0 = s.recorder.as_ref().map(|r| r.now_us());
            s.gram.rebuild(ctx.kf, &mut s.kn);
            if let Some(r) = s.recorder.as_ref() {
                r.span_since(Stage::LayerGram, li, t0.unwrap_or(0),
                             ctx.x.rows as u32);
            }
            let t1 = s.recorder.as_ref().map(|r| r.now_us());
            plan_with_gram_into(mode, ctx, &s.gram, rng, &mut s.energy,
                                &mut s.plan_bufs, &mut s.plan);
            let (e_mean, e_max, e_p90) = if observed {
                energy_summary(&s.energy)
            } else {
                (0.0, 0.0, 0.0)
            };
            if let Some(r) = s.recorder.as_ref() {
                r.record(SpanEvent {
                    stage: Stage::LayerPlan,
                    id: li,
                    t_start_us: t1.unwrap_or(0),
                    t_end_us: r.now_us(),
                    payload: ctx.protect_first as u32,
                    a: e_max,
                    b: e_mean,
                });
            }
            let t2 = s.recorder.as_ref().map(|r| r.now_us());
            apply_plan_into(ctx.x, ctx.sizes, &s.plan, &mut s.out_x,
                            &mut s.out_sizes);
            s.note_apply(li, ctx, e_mean, e_max, e_p90, t2, observed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, h: usize, seed: u64) -> (Mat, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let m = Mat::from_fn(n, h, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let sizes = vec![1.0; n];
        (m, sizes)
    }

    #[test]
    fn all_modes_reduce_by_k() {
        let (x, sizes) = mk(25, 8, 3);
        let attn: Vec<f32> = (0..25).map(|i| 0.01 * i as f32).collect();
        for &mode in &[
            MergeMode::PiToMe, MergeMode::PiToMeNoProtect, MergeMode::PiToMeRandomSplit,
            MergeMode::PiToMeAttn, MergeMode::ToMe, MergeMode::ToFu, MergeMode::Dct,
            MergeMode::DiffRate, MergeMode::Random,
        ] {
            let mut rng = Rng::new(1);
            let ctx = MergeCtx {
                x: &x, kf: &x, sizes: &sizes, attn_cls: &attn,
                margin: 0.4, k: 6, protect_first: 1,
                tofu_threshold: crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD,
            };
            let (out, out_sizes) = merge_step(mode, &ctx, &mut rng);
            assert_eq!(out.rows, 19, "{mode:?}");
            assert_eq!(out_sizes.len(), 19, "{mode:?}");
        }
    }

    #[test]
    fn exactly_one_gram_per_merge_step() {
        let (x, sizes) = mk(25, 8, 3);
        let attn: Vec<f32> = (0..25).map(|i| 0.01 * i as f32).collect();
        let step = |mode| {
            let mut rng = Rng::new(1);
            let ctx = MergeCtx {
                x: &x, kf: &x, sizes: &sizes, attn_cls: &attn,
                margin: 0.4, k: 6, protect_first: 1,
                tofu_threshold: crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD,
            };
            let before = crate::tensor::gram_builds_this_thread();
            merge_step(mode, &ctx, &mut rng);
            crate::tensor::gram_builds_this_thread() - before
        };
        for mode in [
            MergeMode::PiToMe, MergeMode::PiToMeNoProtect,
            MergeMode::PiToMeRandomSplit, MergeMode::PiToMeAttn,
            MergeMode::ToMe, MergeMode::ToFu, MergeMode::DiffRate,
        ] {
            assert_eq!(step(mode), 1, "{mode:?} must build exactly one Gram");
        }
        // similarity-free baselines build none
        for mode in [MergeMode::Dct, MergeMode::Random] {
            assert_eq!(step(mode), 0, "{mode:?} must build no Gram");
        }
    }

    #[test]
    fn scratch_step_matches_allocating_step_for_all_modes() {
        let (x, sizes) = mk(25, 8, 3);
        let attn: Vec<f32> = (0..25).map(|i| 0.01 * i as f32).collect();
        let mut s = MergeScratch::new();
        for &mode in &[
            MergeMode::None, MergeMode::PiToMe, MergeMode::PiToMeNoProtect,
            MergeMode::PiToMeRandomSplit, MergeMode::PiToMeAttn, MergeMode::ToMe,
            MergeMode::ToFu, MergeMode::Dct, MergeMode::DiffRate, MergeMode::Random,
        ] {
            let k = if mode == MergeMode::None { 0 } else { 6 };
            let ctx = MergeCtx {
                x: &x, kf: &x, sizes: &sizes, attn_cls: &attn,
                margin: 0.4, k, protect_first: 1,
                tofu_threshold: crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD,
            };
            let mut r1 = Rng::new(1);
            let (want, want_sizes) = merge_step(mode, &ctx, &mut r1);
            let mut r2 = Rng::new(1);
            // the same scratch is reused across every mode on purpose
            merge_step_scratch(mode, &ctx, &mut r2, &mut s);
            assert_eq!(s.out_x.rows, want.rows, "{mode:?}");
            assert!(s.out_x.max_abs_diff(&want) == 0.0, "{mode:?}");
            assert_eq!(s.out_sizes, want_sizes, "{mode:?}");
        }
    }

    #[test]
    fn scratch_step_builds_exactly_one_gram() {
        let (x, sizes) = mk(25, 8, 3);
        let attn: Vec<f32> = (0..25).map(|i| 0.01 * i as f32).collect();
        let mut s = MergeScratch::new();
        let mut step = |mode| {
            let mut rng = Rng::new(1);
            let ctx = MergeCtx {
                x: &x, kf: &x, sizes: &sizes, attn_cls: &attn,
                margin: 0.4, k: 6, protect_first: 1,
                tofu_threshold: crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD,
            };
            let before = crate::tensor::gram_builds_this_thread();
            merge_step_scratch(mode, &ctx, &mut rng, &mut s);
            crate::tensor::gram_builds_this_thread() - before
        };
        for mode in [
            MergeMode::PiToMe, MergeMode::PiToMeNoProtect,
            MergeMode::PiToMeRandomSplit, MergeMode::PiToMeAttn,
            MergeMode::ToMe, MergeMode::ToFu, MergeMode::DiffRate,
        ] {
            assert_eq!(step(mode), 1, "{mode:?} must rebuild exactly one Gram");
        }
        for mode in [MergeMode::Dct, MergeMode::Random] {
            assert_eq!(step(mode), 0, "{mode:?} must build no Gram");
        }
    }

    #[test]
    fn tofu_threshold_is_sweepable() {
        // orthogonal candidate groups force low-similarity pairs: a high
        // threshold prunes them, threshold -1 merges everything.
        let kf = Mat::from_fn(9, 2, |i, j| {
            if i == 0 { 0.5 }
            else if i % 2 == 1 { if j == 0 { 1.0 } else { 0.0 } }
            else if j == 1 { 1.0 } else { 0.0 }
        });
        let sizes = vec![1.0; 9];
        let attn = vec![0.0; 9];
        let run = |threshold: f32| {
            let mut rng = Rng::new(1);
            let ctx = MergeCtx {
                x: &kf, kf: &kf, sizes: &sizes, attn_cls: &attn,
                margin: 0.4, k: 2, protect_first: 1,
                tofu_threshold: threshold,
            };
            let (_, out_sizes) = merge_step(MergeMode::ToFu, &ctx, &mut rng);
            out_sizes.iter().sum::<f32>()
        };
        assert!(run(0.99) < 9.0 - 0.5, "high threshold must prune mass");
        assert!((run(-1.0) - 9.0).abs() < 1e-4, "threshold -1 must merge all");
    }

    #[test]
    fn size_conservation_for_merging_modes() {
        let (x, sizes) = mk(31, 8, 5);
        let attn: Vec<f32> = (0..31).map(|i| 0.02 * (i % 7) as f32).collect();
        for &mode in &[MergeMode::PiToMe, MergeMode::ToMe, MergeMode::DiffRate] {
            let mut rng = Rng::new(2);
            let ctx = MergeCtx {
                x: &x, kf: &x, sizes: &sizes, attn_cls: &attn,
                margin: 0.4, k: 9, protect_first: 1,
                tofu_threshold: crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD,
            };
            let (_, out_sizes) = merge_step(mode, &ctx, &mut rng);
            let total: f32 = out_sizes.iter().sum();
            assert!((total - 31.0).abs() < 1e-3, "{mode:?} {total}");
        }
    }

    /// Telemetry + spans ride the scratch step without changing its
    /// numerics: every observed mode produces one row with the real
    /// before/after counts, the similarity-driven step records gram/
    /// plan/apply spans, and the energy summary matches a direct
    /// summary of the step's ranking signal.
    #[test]
    fn scratch_step_captures_telemetry_and_spans() {
        let (x, sizes) = mk(25, 8, 3);
        let attn: Vec<f32> = (0..25).map(|i| 0.01 * i as f32).collect();
        let ctx = MergeCtx {
            x: &x, kf: &x, sizes: &sizes, attn_cls: &attn,
            margin: 0.4, k: 6, protect_first: 1,
            tofu_threshold: crate::config::DEFAULT_TOFU_PRUNE_THRESHOLD,
        };
        let mut bare = MergeScratch::new();
        let mut r1 = Rng::new(1);
        merge_step_scratch(MergeMode::PiToMe, &ctx, &mut r1, &mut bare);

        let ring = crate::obs::SpanRing::with_capacity(64);
        let mut s = MergeScratch::new();
        s.telemetry.enable(8);
        s.recorder = Some(ring.writer(std::time::Instant::now()));
        s.telemetry.set_layer(5);
        let mut r2 = Rng::new(1);
        merge_step_scratch(MergeMode::PiToMe, &ctx, &mut r2, &mut s);
        assert_eq!(s.out_x.rows, bare.out_x.rows,
                   "observation must not change the merge");
        assert!(s.out_x.max_abs_diff(&bare.out_x) == 0.0);

        let rows = s.telemetry.rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].layer, 5);
        assert_eq!(rows[0].tokens_before, 25);
        assert_eq!(rows[0].tokens_after, 19);
        assert_eq!(rows[0].protected, 1);
        assert!(rows[0].energy_max >= rows[0].energy_p90);
        assert!(rows[0].energy_max >= rows[0].energy_mean);
        let mut events = Vec::new();
        ring.drain_into(&mut events);
        let stages: Vec<Stage> = events.iter().map(|e| e.stage).collect();
        assert_eq!(stages, vec![Stage::LayerGram, Stage::LayerPlan,
                                Stage::LayerApply]);
        assert!(events.iter().all(|e| e.id == 5));
        assert_eq!(events[2].payload, (25 << 16) | 19);

        // similarity-free baseline: apply span + zero-energy row
        s.telemetry.reset();
        s.telemetry.set_layer(2);
        let mut r3 = Rng::new(1);
        merge_step_scratch(MergeMode::Random, &ctx, &mut r3, &mut s);
        assert_eq!(s.telemetry.rows().len(), 1);
        assert_eq!(s.telemetry.rows()[0].energy_max, 0.0);
        events.clear();
        ring.drain_into(&mut events);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].stage, Stage::LayerApply);
    }

    #[test]
    fn mode_roundtrip_names() {
        for &m in MergeMode::all() {
            assert_eq!(MergeMode::parse(m.name()), Some(m));
        }
        assert_eq!(MergeMode::parse("nonsense"), None);
    }
}
