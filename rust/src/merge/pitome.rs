//! PiToMe: energy-ordered bipartite soft matching with protection (Alg. 1).

use super::plan::{MergePlan, PlanScratch};
use crate::data::Rng;
use crate::tensor::{argsort_desc_into, CosineGram, Mat};

/// How merge candidates are split into sets A and B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// alternate in energy order (the paper's choice: neighbours in the
    /// sorted energy vector likely belong to the same object)
    Alternate,
    /// random assignment (Table 1 ablation)
    Random,
}

/// Build the PiToMe plan from key features (convenience wrapper: builds
/// its own [`CosineGram`]).  The merge hot path shares one Gram between
/// this and the energy score via [`ordered_bsm_plan_gram`].
pub fn ordered_bsm_plan(
    kf: &Mat,
    scores: &[f32],
    k: usize,
    protect_first: usize,
    split: Split,
    protect: bool,
    rng: &mut Rng,
) -> MergePlan {
    ordered_bsm_plan_gram(&CosineGram::build(kf), scores, k, protect_first,
                          split, protect, rng)
}

/// Build the PiToMe plan from a precomputed shared Gram (allocating
/// wrapper over [`ordered_bsm_plan_gram_into`]).
pub fn ordered_bsm_plan_gram(
    g: &CosineGram,
    scores: &[f32],
    k: usize,
    protect_first: usize,
    split: Split,
    protect: bool,
    rng: &mut Rng,
) -> MergePlan {
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    ordered_bsm_plan_gram_into(g, scores, k, protect_first, split, protect,
                               rng, &mut scratch, &mut plan);
    plan
}

/// Build the PiToMe plan from a precomputed shared Gram into a reusable
/// [`MergePlan`] + [`PlanScratch`] — allocation-free once both have seen
/// their largest shape (the steady-state form the merge hot path runs
/// on; see the in-place lifecycle in [`super::plan`]).
///
/// * `scores` — ranking signal, higher = more mergeable (energy, or
///   `-attn_cls` for the attention-indicator ablation).
/// * `protect` — if false, *all* candidates enter the matching and only the
///   `k` most-similar pairs merge (no-protection ablation).
///
/// `k` is clamped to `(n - protect_first) / 2`: with `2k + protect_first
/// > n` the candidate slice would otherwise reach into the protected
/// prefix (whose scores are sunk to `NEG_INFINITY`) and merge protected
/// tokens — or panic outright when `2k > n`.
#[allow(clippy::too_many_arguments)]
pub fn ordered_bsm_plan_gram_into(
    g: &CosineGram,
    scores: &[f32],
    k: usize,
    protect_first: usize,
    split: Split,
    protect: bool,
    rng: &mut Rng,
    s: &mut PlanScratch,
    out: &mut MergePlan,
) {
    let n = g.n();
    assert_eq!(scores.len(), n);
    let k = k.min(n.saturating_sub(protect_first) / 2);
    out.clear();
    // sink protected prefix below every candidate
    s.scores_tmp.clear();
    s.scores_tmp.extend_from_slice(scores);
    for it in s.scores_tmp.iter_mut().take(protect_first) {
        *it = f32::NEG_INFINITY;
    }
    argsort_desc_into(&s.scores_tmp, &mut s.order);

    let n_pairs = if protect { k } else { (n - protect_first) / 2 };
    s.merge_idx.clear();
    s.merge_idx.extend_from_slice(&s.order[..2 * n_pairs]);
    // the rest of the energy order is protected output
    out.protect.extend_from_slice(&s.order[2 * n_pairs..]);
    if split == Split::Random {
        // Fisher-Yates on the candidate list
        for i in (1..s.merge_idx.len()).rev() {
            let j = rng.next_below((i + 1) as u64) as usize;
            s.merge_idx.swap(i, j);
        }
    }
    s.a_all.clear();
    s.a_all.extend(s.merge_idx.iter().step_by(2).copied());
    out.b.extend(s.merge_idx.iter().skip(1).step_by(2).copied());

    // pair similarity: O(1) lookups into the shared Gram
    s.best.clear();
    s.best.resize(s.a_all.len(), f32::NEG_INFINITY);
    s.dst_all.clear();
    s.dst_all.resize(s.a_all.len(), 0);
    for (ai, &aidx) in s.a_all.iter().enumerate() {
        if let Some((bi, d)) = g.best_match(aidx, &out.b, 0) {
            s.best[ai] = d;
            s.dst_all[ai] = bi;
        }
    }

    if n_pairs == k {
        out.a.extend_from_slice(&s.a_all);
        out.dst.extend_from_slice(&s.dst_all);
    } else {
        // keep only the k most-similar pairs; surviving A tokens protected
        argsort_desc_into(&s.best, &mut s.pair_rank);
        for &p in s.pair_rank.iter().take(k) {
            out.a.push(s.a_all[p]);
            out.dst.push(s.dst_all[p]);
        }
        for &p in s.pair_rank.iter().skip(k) {
            out.protect.push(s.a_all[p]);
        }
    }
    out.protect.sort_unstable();
    out.gate.resize(out.a.len(), 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::energy::energy_scores;
    use crate::merge::plan::apply_plan;

    fn clustered(n_cluster: usize, n_iso: usize, h: usize) -> Mat {
        let mut rng = Rng::new(11);
        let center: Vec<f32> =
            (0..h).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        Mat::from_fn(1 + n_cluster + n_iso, h, |i, j| {
            if i == 0 {
                0.0 // CLS
            } else if i <= n_cluster {
                center[j] + 0.01 * (rng.next_f64() as f32 - 0.5)
            } else {
                -center[j] * (1.0 + 0.5 * (i - n_cluster) as f32)
                    + (rng.next_f64() as f32 - 0.5)
            }
        })
    }

    #[test]
    fn protects_isolated_tokens() {
        let kf = clustered(20, 4, 8);
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        let plan =
            ordered_bsm_plan(&kf, &e, 6, 1, Split::Alternate, true, &mut rng);
        plan.validate(kf.rows).unwrap();
        // all merged candidates come from the cluster [1, 20]
        for &i in plan.a.iter().chain(&plan.b) {
            assert!((1..=20).contains(&i), "iso token {i} merged");
        }
        // CLS protected
        assert_eq!(plan.protect[0], 0);
    }

    #[test]
    fn plan_sizes_consistent() {
        let kf = clustered(12, 3, 8);
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        for &(protect, k) in &[(true, 4usize), (false, 4)] {
            let plan = ordered_bsm_plan(
                &kf, &e, k, 1, Split::Alternate, protect, &mut rng);
            plan.validate(kf.rows).unwrap();
            assert_eq!(plan.n_out(), kf.rows - k, "protect={protect}");
        }
    }

    #[test]
    fn random_split_still_valid() {
        let kf = clustered(16, 2, 8);
        let e = energy_scores(&kf, 0.4);
        let mut rng = Rng::new(7);
        let plan = ordered_bsm_plan(&kf, &e, 5, 1, Split::Random, true, &mut rng);
        plan.validate(kf.rows).unwrap();
        let x = kf.clone();
        let (out, sizes) = apply_plan(&x, &vec![1.0; kf.rows], &plan);
        assert_eq!(out.rows, kf.rows - 5);
        let total: f32 = sizes.iter().sum();
        assert!((total - kf.rows as f32).abs() < 1e-3);
    }

    #[test]
    fn oversized_k_is_clamped_and_never_touches_protected() {
        // regression: with 2k + protect_first > n the old candidate slice
        // pulled NEG_INFINITY-scored protected tokens into the matching
        // (or panicked outright when 2k > n).
        for (n, protect_first, k) in
            [(9usize, 1usize, 10usize), (5, 1, 7), (8, 3, 4), (6, 1, 3),
             (4, 2, 5), (7, 7, 2), (3, 1, 1)] {
            let mut rng = Rng::new(3);
            let kf = Mat::from_fn(n, 6, |i, j| ((i * 7 + j * 3) % 5) as f32 - 2.0);
            let e = energy_scores(&kf, 0.4);
            for protect in [true, false] {
                let mut r2 = Rng::new(4);
                let plan = ordered_bsm_plan(
                    &kf, &e, k, protect_first, Split::Alternate, protect, &mut r2);
                plan.validate(n).unwrap();
                let k_eff = k.min((n - protect_first.min(n)) / 2);
                assert!(plan.n_out() >= n - k_eff,
                        "n={n} pf={protect_first} k={k}: removed too many");
                for &i in plan.a.iter().chain(&plan.b) {
                    assert!(i >= protect_first,
                            "protected token {i} entered matching \
                             (n={n} pf={protect_first} k={k} protect={protect})");
                }
                for p in 0..protect_first.min(n) {
                    assert!(plan.protect.contains(&p),
                            "protected token {p} missing from output");
                }
            }
            // random split on the clamped candidate set stays valid too
            let plan = ordered_bsm_plan(
                &kf, &e, k, protect_first, Split::Random, true, &mut rng);
            plan.validate(n).unwrap();
        }
    }

    #[test]
    fn gram_and_direct_paths_agree() {
        let kf = clustered(14, 3, 8);
        let g = crate::tensor::CosineGram::build(&kf);
        let e = crate::merge::energy::energy_from_gram(&g, 0.5);
        let mut r1 = Rng::new(6);
        let mut r2 = Rng::new(6);
        let p1 = ordered_bsm_plan(&kf, &e, 5, 1, Split::Alternate, true, &mut r1);
        let p2 = ordered_bsm_plan_gram(&g, &e, 5, 1, Split::Alternate, true, &mut r2);
        assert_eq!(p1.a, p2.a);
        assert_eq!(p1.b, p2.b);
        assert_eq!(p1.dst, p2.dst);
        assert_eq!(p1.protect, p2.protect);
    }

    #[test]
    fn identical_cluster_merges_to_center() {
        // all candidates identical: any merge preserves the value exactly
        let h = 4;
        let kf = Mat::from_fn(9, h, |i, j| if i == 0 { 0.0 } else { (j + 1) as f32 });
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        let plan = ordered_bsm_plan(&kf, &e, 3, 1, Split::Alternate, true, &mut rng);
        let (out, _) = apply_plan(&kf, &vec![1.0; 9], &plan);
        for bi in 0..plan.b.len() {
            let r = out.row(plan.protect.len() + bi);
            for (j, &v) in r.iter().enumerate() {
                assert!((v - (j + 1) as f32).abs() < 1e-5);
            }
        }
    }
}
