//! PiToMe: energy-ordered bipartite soft matching with protection (Alg. 1).

use super::plan::MergePlan;
use crate::data::Rng;
use crate::tensor::{argsort_desc, normalize_rows, Mat};

/// How merge candidates are split into sets A and B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// alternate in energy order (the paper's choice: neighbours in the
    /// sorted energy vector likely belong to the same object)
    Alternate,
    /// random assignment (Table 1 ablation)
    Random,
}

/// Build the PiToMe plan.
///
/// * `scores` — ranking signal, higher = more mergeable (energy, or
///   `-attn_cls` for the attention-indicator ablation).
/// * `protect` — if false, *all* candidates enter the matching and only the
///   `k` most-similar pairs merge (no-protection ablation).
pub fn ordered_bsm_plan(
    kf: &Mat,
    scores: &[f32],
    k: usize,
    protect_first: usize,
    split: Split,
    protect: bool,
    rng: &mut Rng,
) -> MergePlan {
    let n = kf.rows;
    assert_eq!(scores.len(), n);
    // sink protected prefix below every candidate
    let mut s_cand = scores.to_vec();
    for it in s_cand.iter_mut().take(protect_first) {
        *it = f32::NEG_INFINITY;
    }
    let order = argsort_desc(&s_cand);

    let n_pairs = if protect { k } else { (n - protect_first) / 2 };
    let mut merge_idx: Vec<usize> = order[..2 * n_pairs].to_vec();
    let rest: Vec<usize> = order[2 * n_pairs..].to_vec();
    if split == Split::Random {
        // Fisher-Yates on the candidate list
        for i in (1..merge_idx.len()).rev() {
            let j = rng.next_below((i + 1) as u64) as usize;
            merge_idx.swap(i, j);
        }
    }
    let a_all: Vec<usize> = merge_idx.iter().step_by(2).copied().collect();
    let b: Vec<usize> = merge_idx.iter().skip(1).step_by(2).copied().collect();

    // pair similarity via normalized dot products
    let kn = normalize_rows(kf);
    let mut best = vec![f32::NEG_INFINITY; a_all.len()];
    let mut dst_all = vec![0usize; a_all.len()];
    for (ai, &aidx) in a_all.iter().enumerate() {
        let ra = kn.row(aidx);
        for (bi, &bidx) in b.iter().enumerate() {
            let rb = kn.row(bidx);
            let mut dot = 0f32;
            for c in 0..kn.cols {
                dot += ra[c] * rb[c];
            }
            if dot > best[ai] {
                best[ai] = dot;
                dst_all[ai] = bi;
            }
        }
    }

    let mut protect_idx: Vec<usize>;
    let (a, dst) = if n_pairs == k {
        protect_idx = rest;
        (a_all, dst_all)
    } else {
        // keep only the k most-similar pairs; surviving A tokens protected
        let pair_rank = argsort_desc(&best);
        let mut a_merge = Vec::with_capacity(k);
        let mut dst = Vec::with_capacity(k);
        for &p in pair_rank.iter().take(k) {
            a_merge.push(a_all[p]);
            dst.push(dst_all[p]);
        }
        protect_idx = rest;
        for &p in pair_rank.iter().skip(k) {
            protect_idx.push(a_all[p]);
        }
        (a_merge, dst)
    };
    protect_idx.sort_unstable();
    MergePlan { protect: protect_idx, a, b, dst, gate: vec![1.0; k] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::energy::energy_scores;
    use crate::merge::plan::apply_plan;

    fn clustered(n_cluster: usize, n_iso: usize, h: usize) -> Mat {
        let mut rng = Rng::new(11);
        let center: Vec<f32> =
            (0..h).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        Mat::from_fn(1 + n_cluster + n_iso, h, |i, j| {
            if i == 0 {
                0.0 // CLS
            } else if i <= n_cluster {
                center[j] + 0.01 * (rng.next_f64() as f32 - 0.5)
            } else {
                -center[j] * (1.0 + 0.5 * (i - n_cluster) as f32)
                    + (rng.next_f64() as f32 - 0.5)
            }
        })
    }

    #[test]
    fn protects_isolated_tokens() {
        let kf = clustered(20, 4, 8);
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        let plan =
            ordered_bsm_plan(&kf, &e, 6, 1, Split::Alternate, true, &mut rng);
        plan.validate(kf.rows).unwrap();
        // all merged candidates come from the cluster [1, 20]
        for &i in plan.a.iter().chain(&plan.b) {
            assert!((1..=20).contains(&i), "iso token {i} merged");
        }
        // CLS protected
        assert_eq!(plan.protect[0], 0);
    }

    #[test]
    fn plan_sizes_consistent() {
        let kf = clustered(12, 3, 8);
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        for &(protect, k) in &[(true, 4usize), (false, 4)] {
            let plan = ordered_bsm_plan(
                &kf, &e, k, 1, Split::Alternate, protect, &mut rng);
            plan.validate(kf.rows).unwrap();
            assert_eq!(plan.n_out(), kf.rows - k, "protect={protect}");
        }
    }

    #[test]
    fn random_split_still_valid() {
        let kf = clustered(16, 2, 8);
        let e = energy_scores(&kf, 0.4);
        let mut rng = Rng::new(7);
        let plan = ordered_bsm_plan(&kf, &e, 5, 1, Split::Random, true, &mut rng);
        plan.validate(kf.rows).unwrap();
        let x = kf.clone();
        let (out, sizes) = apply_plan(&x, &vec![1.0; kf.rows], &plan);
        assert_eq!(out.rows, kf.rows - 5);
        let total: f32 = sizes.iter().sum();
        assert!((total - kf.rows as f32).abs() < 1e-3);
    }

    #[test]
    fn identical_cluster_merges_to_center() {
        // all candidates identical: any merge preserves the value exactly
        let h = 4;
        let kf = Mat::from_fn(9, h, |i, j| if i == 0 { 0.0 } else { (j + 1) as f32 });
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        let plan = ordered_bsm_plan(&kf, &e, 3, 1, Split::Alternate, true, &mut rng);
        let (out, _) = apply_plan(&kf, &vec![1.0; 9], &plan);
        for bi in 0..plan.b.len() {
            let r = out.row(plan.protect.len() + bi);
            for (j, &v) in r.iter().enumerate() {
                assert!((v - (j + 1) as f32).abs() < 1e-5);
            }
        }
    }
}
