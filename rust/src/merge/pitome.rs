//! PiToMe: energy-ordered bipartite soft matching with protection (Alg. 1).

use super::plan::MergePlan;
use crate::data::Rng;
use crate::tensor::{argsort_desc, CosineGram, Mat};

/// How merge candidates are split into sets A and B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// alternate in energy order (the paper's choice: neighbours in the
    /// sorted energy vector likely belong to the same object)
    Alternate,
    /// random assignment (Table 1 ablation)
    Random,
}

/// Build the PiToMe plan from key features (convenience wrapper: builds
/// its own [`CosineGram`]).  The merge hot path shares one Gram between
/// this and the energy score via [`ordered_bsm_plan_gram`].
pub fn ordered_bsm_plan(
    kf: &Mat,
    scores: &[f32],
    k: usize,
    protect_first: usize,
    split: Split,
    protect: bool,
    rng: &mut Rng,
) -> MergePlan {
    ordered_bsm_plan_gram(&CosineGram::build(kf), scores, k, protect_first,
                          split, protect, rng)
}

/// Build the PiToMe plan from a precomputed shared Gram.
///
/// * `scores` — ranking signal, higher = more mergeable (energy, or
///   `-attn_cls` for the attention-indicator ablation).
/// * `protect` — if false, *all* candidates enter the matching and only the
///   `k` most-similar pairs merge (no-protection ablation).
///
/// `k` is clamped to `(n - protect_first) / 2`: with `2k + protect_first
/// > n` the candidate slice would otherwise reach into the protected
/// prefix (whose scores are sunk to `NEG_INFINITY`) and merge protected
/// tokens — or panic outright when `2k > n`.
pub fn ordered_bsm_plan_gram(
    g: &CosineGram,
    scores: &[f32],
    k: usize,
    protect_first: usize,
    split: Split,
    protect: bool,
    rng: &mut Rng,
) -> MergePlan {
    let n = g.n();
    assert_eq!(scores.len(), n);
    let k = k.min(n.saturating_sub(protect_first) / 2);
    // sink protected prefix below every candidate
    let mut s_cand = scores.to_vec();
    for it in s_cand.iter_mut().take(protect_first) {
        *it = f32::NEG_INFINITY;
    }
    let order = argsort_desc(&s_cand);

    let n_pairs = if protect { k } else { (n - protect_first) / 2 };
    let mut merge_idx: Vec<usize> = order[..2 * n_pairs].to_vec();
    let rest: Vec<usize> = order[2 * n_pairs..].to_vec();
    if split == Split::Random {
        // Fisher-Yates on the candidate list
        for i in (1..merge_idx.len()).rev() {
            let j = rng.next_below((i + 1) as u64) as usize;
            merge_idx.swap(i, j);
        }
    }
    let a_all: Vec<usize> = merge_idx.iter().step_by(2).copied().collect();
    let b: Vec<usize> = merge_idx.iter().skip(1).step_by(2).copied().collect();

    // pair similarity: O(1) lookups into the shared Gram
    let mut best = vec![f32::NEG_INFINITY; a_all.len()];
    let mut dst_all = vec![0usize; a_all.len()];
    for (ai, &aidx) in a_all.iter().enumerate() {
        if let Some((bi, d)) = g.best_match(aidx, &b, 0) {
            best[ai] = d;
            dst_all[ai] = bi;
        }
    }

    let mut protect_idx: Vec<usize>;
    let (a, dst) = if n_pairs == k {
        protect_idx = rest;
        (a_all, dst_all)
    } else {
        // keep only the k most-similar pairs; surviving A tokens protected
        let pair_rank = argsort_desc(&best);
        let mut a_merge = Vec::with_capacity(k);
        let mut dst = Vec::with_capacity(k);
        for &p in pair_rank.iter().take(k) {
            a_merge.push(a_all[p]);
            dst.push(dst_all[p]);
        }
        protect_idx = rest;
        for &p in pair_rank.iter().skip(k) {
            protect_idx.push(a_all[p]);
        }
        (a_merge, dst)
    };
    protect_idx.sort_unstable();
    let gate = vec![1.0; a.len()];
    MergePlan { protect: protect_idx, a, b, dst, gate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merge::energy::energy_scores;
    use crate::merge::plan::apply_plan;

    fn clustered(n_cluster: usize, n_iso: usize, h: usize) -> Mat {
        let mut rng = Rng::new(11);
        let center: Vec<f32> =
            (0..h).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        Mat::from_fn(1 + n_cluster + n_iso, h, |i, j| {
            if i == 0 {
                0.0 // CLS
            } else if i <= n_cluster {
                center[j] + 0.01 * (rng.next_f64() as f32 - 0.5)
            } else {
                -center[j] * (1.0 + 0.5 * (i - n_cluster) as f32)
                    + (rng.next_f64() as f32 - 0.5)
            }
        })
    }

    #[test]
    fn protects_isolated_tokens() {
        let kf = clustered(20, 4, 8);
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        let plan =
            ordered_bsm_plan(&kf, &e, 6, 1, Split::Alternate, true, &mut rng);
        plan.validate(kf.rows).unwrap();
        // all merged candidates come from the cluster [1, 20]
        for &i in plan.a.iter().chain(&plan.b) {
            assert!((1..=20).contains(&i), "iso token {i} merged");
        }
        // CLS protected
        assert_eq!(plan.protect[0], 0);
    }

    #[test]
    fn plan_sizes_consistent() {
        let kf = clustered(12, 3, 8);
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        for &(protect, k) in &[(true, 4usize), (false, 4)] {
            let plan = ordered_bsm_plan(
                &kf, &e, k, 1, Split::Alternate, protect, &mut rng);
            plan.validate(kf.rows).unwrap();
            assert_eq!(plan.n_out(), kf.rows - k, "protect={protect}");
        }
    }

    #[test]
    fn random_split_still_valid() {
        let kf = clustered(16, 2, 8);
        let e = energy_scores(&kf, 0.4);
        let mut rng = Rng::new(7);
        let plan = ordered_bsm_plan(&kf, &e, 5, 1, Split::Random, true, &mut rng);
        plan.validate(kf.rows).unwrap();
        let x = kf.clone();
        let (out, sizes) = apply_plan(&x, &vec![1.0; kf.rows], &plan);
        assert_eq!(out.rows, kf.rows - 5);
        let total: f32 = sizes.iter().sum();
        assert!((total - kf.rows as f32).abs() < 1e-3);
    }

    #[test]
    fn oversized_k_is_clamped_and_never_touches_protected() {
        // regression: with 2k + protect_first > n the old candidate slice
        // pulled NEG_INFINITY-scored protected tokens into the matching
        // (or panicked outright when 2k > n).
        for (n, protect_first, k) in
            [(9usize, 1usize, 10usize), (5, 1, 7), (8, 3, 4), (6, 1, 3),
             (4, 2, 5), (7, 7, 2), (3, 1, 1)] {
            let mut rng = Rng::new(3);
            let kf = Mat::from_fn(n, 6, |i, j| ((i * 7 + j * 3) % 5) as f32 - 2.0);
            let e = energy_scores(&kf, 0.4);
            for protect in [true, false] {
                let mut r2 = Rng::new(4);
                let plan = ordered_bsm_plan(
                    &kf, &e, k, protect_first, Split::Alternate, protect, &mut r2);
                plan.validate(n).unwrap();
                let k_eff = k.min((n - protect_first.min(n)) / 2);
                assert!(plan.n_out() >= n - k_eff,
                        "n={n} pf={protect_first} k={k}: removed too many");
                for &i in plan.a.iter().chain(&plan.b) {
                    assert!(i >= protect_first,
                            "protected token {i} entered matching \
                             (n={n} pf={protect_first} k={k} protect={protect})");
                }
                for p in 0..protect_first.min(n) {
                    assert!(plan.protect.contains(&p),
                            "protected token {p} missing from output");
                }
            }
            // random split on the clamped candidate set stays valid too
            let plan = ordered_bsm_plan(
                &kf, &e, k, protect_first, Split::Random, true, &mut rng);
            plan.validate(n).unwrap();
        }
    }

    #[test]
    fn gram_and_direct_paths_agree() {
        let kf = clustered(14, 3, 8);
        let g = crate::tensor::CosineGram::build(&kf);
        let e = crate::merge::energy::energy_from_gram(&g, 0.5);
        let mut r1 = Rng::new(6);
        let mut r2 = Rng::new(6);
        let p1 = ordered_bsm_plan(&kf, &e, 5, 1, Split::Alternate, true, &mut r1);
        let p2 = ordered_bsm_plan_gram(&g, &e, 5, 1, Split::Alternate, true, &mut r2);
        assert_eq!(p1.a, p2.a);
        assert_eq!(p1.b, p2.b);
        assert_eq!(p1.dst, p2.dst);
        assert_eq!(p1.protect, p2.protect);
    }

    #[test]
    fn identical_cluster_merges_to_center() {
        // all candidates identical: any merge preserves the value exactly
        let h = 4;
        let kf = Mat::from_fn(9, h, |i, j| if i == 0 { 0.0 } else { (j + 1) as f32 });
        let e = energy_scores(&kf, 0.5);
        let mut rng = Rng::new(0);
        let plan = ordered_bsm_plan(&kf, &e, 3, 1, Split::Alternate, true, &mut rng);
        let (out, _) = apply_plan(&kf, &vec![1.0; 9], &plan);
        for bi in 0..plan.b.len() {
            let r = out.row(plan.protect.len() + bi);
            for (j, &v) in r.iter().enumerate() {
                assert!((v - (j + 1) as f32).abs() < 1e-5);
            }
        }
    }
}
