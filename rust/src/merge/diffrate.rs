//! DiffRate-style baseline: CLS-attention-ranked merging (fixed schedule).
//!
//! The learned compression-rate search of DiffRate (Chen et al. 2023) is
//! replaced by the same fixed ratio-r schedule every other mode uses
//! (DESIGN.md §6); what remains is its ranking signal: merge the k least
//! CLS-attended tokens into their most similar kept token.

use super::plan::{MergePlan, PlanScratch};
use crate::tensor::{argsort_asc_into, CosineGram, Mat};

/// Build the attention-ranked plan from key features (convenience wrapper:
/// builds its own [`CosineGram`]; the merge hot path shares one via
/// [`diffrate_plan_gram`]).
pub fn diffrate_plan(kf: &Mat, attn_cls: &[f32], k: usize,
                     protect_first: usize) -> MergePlan {
    diffrate_plan_gram(&CosineGram::build(kf), attn_cls, k, protect_first)
}

/// Build the attention-ranked plan from a precomputed shared Gram
/// (allocating wrapper over [`diffrate_plan_gram_into`]).
pub fn diffrate_plan_gram(g: &CosineGram, attn_cls: &[f32], k: usize,
                          protect_first: usize) -> MergePlan {
    let mut scratch = PlanScratch::new();
    let mut plan = MergePlan::empty();
    diffrate_plan_gram_into(g, attn_cls, k, protect_first, &mut scratch,
                            &mut plan);
    plan
}

/// Build the attention-ranked plan from a precomputed shared Gram into a
/// reusable [`MergePlan`] + [`PlanScratch`] (allocation-free once warm;
/// see the in-place lifecycle in [`super::plan`]).
pub fn diffrate_plan_gram_into(g: &CosineGram, attn_cls: &[f32], k: usize,
                               protect_first: usize, s: &mut PlanScratch,
                               out: &mut MergePlan) {
    let n = g.n();
    assert_eq!(attn_cls.len(), n);
    out.clear();
    s.scores_tmp.clear();
    s.scores_tmp.extend_from_slice(attn_cls);
    for it in s.scores_tmp.iter_mut().take(protect_first) {
        *it = f32::INFINITY; // CLS never merged away
    }
    argsort_asc_into(&s.scores_tmp, &mut s.order);
    out.a.extend_from_slice(&s.order[..k]);
    out.b.extend_from_slice(&s.order[k..]);
    out.b.sort_unstable();

    out.dst.resize(k, 0);
    for (ai, &aidx) in out.a.iter().enumerate() {
        // CLS (indices below protect_first) cannot receive merges
        if let Some((bi, _)) = g.best_match(aidx, &out.b, protect_first) {
            out.dst[ai] = bi;
        }
    }
    out.gate.resize(k, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::merge::plan::apply_plan;

    #[test]
    fn merges_least_attended() {
        let mut rng = Rng::new(3);
        let kf = Mat::from_fn(13, 6, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32);
        let attn: Vec<f32> = (0..13).map(|i| i as f32 * 0.01).collect();
        let plan = diffrate_plan(&kf, &attn, 4, 1);
        plan.validate(13).unwrap();
        // tokens 1..=4 have the lowest non-CLS attention
        let mut a = plan.a.clone();
        a.sort_unstable();
        assert_eq!(a, vec![1, 2, 3, 4]);
        // CLS is in B but receives no merges
        assert!(plan.b.contains(&0));
        for (&_ai, &d) in plan.a.iter().zip(&plan.dst) {
            assert_ne!(plan.b[d], 0, "CLS received a merge");
        }
        let (out, sizes) = apply_plan(&kf, &vec![1.0; 13], &plan);
        assert_eq!(out.rows, 9);
        assert!((sizes.iter().sum::<f32>() - 13.0).abs() < 1e-4);
    }
}
