//! Dense f32 tensor micro-library.
//!
//! Deliberately tiny: row-major 2-D matrices plus the handful of ops the
//! merge engine, the CPU reference transformer, and the spectral toolkit
//! need.  Not a general ndarray — the point is a dependency-free, auditable
//! substrate whose numerics mirror the JAX reference (`python/compile/
//! kernels/ref.py`) to float tolerance.

mod ops;

pub use ops::*;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// number of rows
    pub rows: usize,
    /// number of columns
    pub cols: usize,
    /// row-major storage, `len == rows * cols`
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from a row-major vector (panics on length mismatch).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec length mismatch");
        Mat { rows, cols, data }
    }

    /// Reshape to `(rows, cols)` in place, reusing the existing allocation
    /// whenever capacity allows.  Contents are unspecified afterwards —
    /// for callers that fully overwrite the matrix (the `_into` ops and
    /// the scratch-workspace forward pass).  Capacity never shrinks, so a
    /// buffer that has seen its largest shape never reallocates again.
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// [`reshape`](Mat::reshape) followed by a zero fill — for accumulator
    /// outputs (`matmul_into`, attention `out += P·V`).
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.reshape(rows, cols);
        self.data.fill(0.0);
    }

    /// Copy `src` into self, reshaping as needed (allocation-free at
    /// steady state).
    pub fn copy_from(&mut self, src: &Mat) {
        self.reshape(src.rows, src.cols);
        self.data.copy_from_slice(&src.data);
    }

    /// Borrowed view (for the `_into` ops, which take weights as views so
    /// parameter matrices are never cloned on the hot path).
    #[inline]
    pub fn view(&self) -> MatRef<'_> {
        MatRef { rows: self.rows, cols: self.cols, data: &self.data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Select rows by index into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (oi, &si) in idx.iter().enumerate() {
            out.row_mut(oi).copy_from_slice(self.row(si));
        }
        out
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "vcat col mismatch");
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Frobenius-norm of the difference (for tests).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

/// Borrowed row-major 2-D view — the weight-side argument of the `_into`
/// ops.  [`ParamStore::mat2_view`](crate::model::ParamStore::mat2_view)
/// hands these out directly over the flat parameter vector, so the
/// steady-state forward pass never copies a weight matrix.
#[derive(Clone, Copy, Debug)]
pub struct MatRef<'a> {
    /// number of rows
    pub rows: usize,
    /// number of columns
    pub cols: usize,
    /// row-major storage, `len == rows * cols`
    pub data: &'a [f32],
}

impl<'a> MatRef<'a> {
    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

impl<'a> From<&'a Mat> for MatRef<'a> {
    fn from(m: &'a Mat) -> MatRef<'a> {
        m.view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_and_accessors() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(0), &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 7 + j * 3) as f32);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn select_rows_picks_rows() {
        let m = Mat::from_fn(4, 2, |i, _| i as f32);
        let s = m.select_rows(&[3, 1]);
        assert_eq!(s.row(0), &[3.0, 3.0]);
        assert_eq!(s.row(1), &[1.0, 1.0]);
    }

    #[test]
    fn reshape_keeps_capacity_and_reset_zeroes() {
        let mut m = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f32 + 1.0);
        let cap = m.data.capacity();
        m.reshape(2, 3);
        assert_eq!((m.rows, m.cols, m.data.len()), (2, 3, 6));
        assert!(m.data.capacity() >= cap, "shrinking must keep capacity");
        m.reset(3, 2);
        assert!(m.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn copy_from_matches_source() {
        let src = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        let mut dst = Mat::zeros(1, 1);
        dst.copy_from(&src);
        assert_eq!(dst, src);
    }

    #[test]
    fn view_rows_match_mat_rows() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let v = m.view();
        for i in 0..3 {
            assert_eq!(v.row(i), m.row(i));
        }
    }

    #[test]
    fn vcat_stacks() {
        let a = Mat::from_fn(1, 2, |_, j| j as f32);
        let b = Mat::from_fn(2, 2, |i, _| i as f32 + 10.0);
        let c = a.vcat(&b);
        assert_eq!(c.rows, 3);
        assert_eq!(c.row(2), &[11.0, 11.0]);
    }
}
