//! Numerical ops over [`Mat`] mirroring `python/compile/kernels/ref.py`.

use super::Mat;

/// C = A @ B (naive ikj loop; the perf pass blocks this — see `matmul`).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dim mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * b.cols..(kk + 1) * b.cols];
            for (cj, &bv) in crow.iter_mut().zip(brow) {
                *cj += av * bv;
            }
        }
    }
    c
}

/// C = A @ B^T — the similarity-matrix shape; avoids materializing B^T.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dim mismatch");
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        for j in 0..b.rows {
            let brow = b.row(j);
            let mut acc = 0.0f32;
            for k in 0..a.cols {
                acc += arow[k] * brow[k];
            }
            c.data[i * b.rows + j] = acc;
        }
    }
    c
}

/// L2-normalize each row (eps matches the JAX reference).
pub fn normalize_rows(m: &Mat) -> Mat {
    let mut out = m.clone();
    for i in 0..m.rows {
        let r = out.row_mut(i);
        let n: f32 = r.iter().map(|v| v * v).sum::<f32>().sqrt() + 1e-6;
        for v in r.iter_mut() {
            *v /= n;
        }
    }
    out
}

/// Pairwise cosine-similarity matrix W (N, N) of row features.
pub fn cosine_matrix(kf: &Mat) -> Mat {
    let kn = normalize_rows(kf);
    matmul_nt(&kn, &kn)
}

/// Row-wise softmax in place.
pub fn softmax_rows(m: &mut Mat) {
    for i in 0..m.rows {
        let r = m.row_mut(i);
        let mx = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in r.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in r.iter_mut() {
            *v /= sum;
        }
    }
}

/// LayerNorm over the last axis with learned scale/shift.
pub fn layernorm(x: &Mat, w: &[f32], b: &[f32], eps: f32) -> Mat {
    assert_eq!(x.cols, w.len());
    assert_eq!(x.cols, b.len());
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let r = x.row(i);
        let mu: f32 = r.iter().sum::<f32>() / x.cols as f32;
        let var: f32 = r.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let o = out.row_mut(i);
        for j in 0..x.cols {
            o[j] = (r[j] - mu) * inv * w[j] + b[j];
        }
    }
    out
}

/// tanh-approximation GELU, matching `model.py::gelu`.
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6_f32 * (x + 0.044_715 * x * x * x)).tanh())
}

/// Apply GELU elementwise in place.
pub fn gelu_inplace(m: &mut Mat) {
    for v in m.data.iter_mut() {
        *v = gelu(*v);
    }
}

/// Indices that sort `vals` descending (stable).
pub fn argsort_desc(vals: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// Indices that sort `vals` ascending (stable).
pub fn argsort_asc(vals: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// argmax over a slice.
pub fn argmax(vals: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in vals.iter().enumerate() {
        if v > vals[best] {
            best = i;
        }
    }
    best
}

/// x @ w + b for a weight matrix (in, out) and bias (out).
pub fn dense(x: &Mat, w: &Mat, b: Option<&[f32]>) -> Mat {
    let mut y = matmul(x, w);
    if let Some(bias) = b {
        assert_eq!(bias.len(), y.cols);
        for i in 0..y.rows {
            let r = y.row_mut(i);
            for j in 0..r.len() {
                r[j] += bias[j];
            }
        }
    }
    y
}

/// Elementwise a += b.
pub fn add_inplace(a: &mut Mat, b: &Mat) {
    assert_eq!(a.rows, b.rows);
    assert_eq!(a.cols, b.cols);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += *y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = Mat::from_fn(3, 4, |i, j| (i + j) as f32 * 0.5);
        let b = Mat::from_fn(5, 4, |i, j| (i * j) as f32 * 0.25 - 1.0);
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-6);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let mut m = Mat::from_fn(2, 4, |i, j| (i * j) as f32);
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f32 = m.row(i).iter().sum();
            assert!(approx(s, 1.0, 1e-6));
        }
    }

    #[test]
    fn cosine_matrix_diag_is_one() {
        let m = Mat::from_fn(4, 8, |i, j| ((i * 13 + j * 7) % 11) as f32 - 5.0);
        let w = cosine_matrix(&m);
        for i in 0..4 {
            assert!(approx(w.get(i, i), 1.0, 1e-3), "diag {}", w.get(i, i));
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = Mat::from_fn(1, 6, |_, j| j as f32 * 2.0);
        let w = vec![1.0; 6];
        let b = vec![0.0; 6];
        let y = layernorm(&x, &w, &b, 1e-5);
        let mu: f32 = y.row(0).iter().sum::<f32>() / 6.0;
        assert!(approx(mu, 0.0, 1e-5));
    }

    #[test]
    fn argsort_desc_orders() {
        assert_eq!(argsort_desc(&[1.0, 3.0, 2.0]), vec![1, 2, 0]);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }
}
