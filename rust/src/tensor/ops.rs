//! Numerical ops over [`Mat`] mirroring `python/compile/kernels/ref.py`,
//! plus the shared [`CosineGram`] the merge engine is built around: one
//! blocked, auto-vectorized cosine Gram per merge step, reused by both the
//! energy score (Eq. 4) and every bipartite-matching plan builder.

use std::cell::Cell;

use super::{Mat, MatRef};

thread_local! {
    /// Per-thread count of [`CosineGram::build`] calls — lets tests assert
    /// "exactly one Gram per merge step" without cross-thread races.
    static GRAM_BUILDS: Cell<usize> = Cell::new(0);
}

/// Number of cosine Grams built on this thread so far (test hook for the
/// one-Gram-per-merge-step invariant).
pub fn gram_builds_this_thread() -> usize {
    GRAM_BUILDS.with(|c| c.get())
}

/// f32 lanes per accumulator block in [`dot`], selected per target
/// (ROADMAP "SIMD-width audit"): 4 on 128-bit NEON targets where an
/// 8-lane block spills to two registers for no gain, 16 on x86-64 built
/// with AVX-512 enabled (`-C target-feature=+avx512f` / a `znver4`-class
/// `target-cpu`) so one accumulator block fills a zmm register, and 8 on
/// the AVX-shaped default.  All widths produce results within float
/// tolerance of each other (parity-tested in this module across
/// 1/2/4/8/16 lanes, plus the target's own default selection).
#[cfg(any(target_arch = "aarch64", target_arch = "arm"))]
pub const DOT_LANES: usize = 4;
/// f32 lanes per accumulator block in [`dot`] (16: one AVX-512 zmm).
#[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
pub const DOT_LANES: usize = 16;
/// f32 lanes per accumulator block in [`dot`] (8: AVX-shaped default).
#[cfg(not(any(target_arch = "aarch64", target_arch = "arm",
              all(target_arch = "x86_64", target_feature = "avx512f"))))]
pub const DOT_LANES: usize = 8;

/// Dot product with `L` independent partial sums (`L` >= 1; powers of
/// two vectorize best).
///
/// A `zip().map().sum()` chain is a single order-constrained reduction
/// LLVM must keep scalar; `L` independent accumulator lanes over
/// `chunks_exact(L)` let it vectorize, which is where the merge engine's
/// O(n²h) Gram time goes.  The lane array is reduced pairwise
/// (stride-halving), which for `L = 8` reproduces the historical
/// `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))` summation order bit-for-bit.
#[inline]
pub fn dot_with_lanes<const L: usize>(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; L];
    let split = a.len() - a.len() % L;
    for (ca, cb) in a[..split].chunks_exact(L).zip(b[..split].chunks_exact(L)) {
        for l in 0..L {
            acc[l] += ca[l] * cb[l];
        }
    }
    let tail: f32 = a[split..].iter().zip(&b[split..]).map(|(x, y)| x * y).sum();
    // stride-halving pairwise reduction down to two partial sums (while the
    // width stays even; an odd width falls through to the linear fold, so
    // every L is summed correctly), then fold the tail in first — for
    // L = 8 this reproduces the historical
    // ((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7)) order bit-for-bit
    let mut width = L;
    while width > 2 && width % 2 == 0 {
        width /= 2;
        for l in 0..width {
            acc[l] += acc[l + width];
        }
    }
    let mut total = tail;
    for &v in acc.iter().take(width) {
        total += v;
    }
    total
}

/// Dot product at the target's [`DOT_LANES`] width.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with_lanes::<DOT_LANES>(a, b)
}

/// The cosine-similarity Gram of one token set — computed **once** per
/// merge step and shared by the energy score and every plan builder
/// (PiToMe / ToMe / ToFu / DiffRate).
///
/// The Gram is symmetric, so only the upper triangle is computed (blocked
/// for cache reuse) and mirrored; the diagonal is pinned to 1.0.  The
/// normalized features themselves are build-local scratch
/// ([`normalize_rows_into`]) and are not retained: with a whole batch of
/// Grams in flight that would duplicate every key-feature matrix for no
/// consumer.  Scratch workspaces rebuild in place via
/// [`CosineGram::rebuild`].
pub struct CosineGram {
    /// pairwise cosine similarities, (n, n), symmetric, diag = 1
    pub w: Mat,
}

impl CosineGram {
    /// Tile side for the blocked triangular Gram.
    const BLOCK: usize = 32;

    /// An empty Gram to rebuild into (scratch workspaces start here).
    pub fn empty() -> CosineGram {
        CosineGram { w: Mat::zeros(0, 0) }
    }

    /// Build the Gram for key features `kf` (n, h) — convenience wrapper
    /// over [`CosineGram::rebuild`] that allocates its own buffers.
    pub fn build(kf: &Mat) -> CosineGram {
        let mut g = CosineGram::empty();
        let mut kn = Mat::zeros(0, 0);
        g.rebuild(kf, &mut kn);
        g
    }

    /// Rebuild this Gram in place from `kf`, reusing `kn` as the
    /// normalized-feature scratch.  Counts as one Gram build for the
    /// one-Gram-per-merge-step invariant; allocation-free once both
    /// buffers have seen their largest shape.
    pub fn rebuild(&mut self, kf: &Mat, kn: &mut Mat) {
        GRAM_BUILDS.with(|c| c.set(c.get() + 1));
        normalize_rows_into(kf, kn);
        let n = kn.rows;
        let w = &mut self.w;
        w.reshape(n, n);
        for ib in (0..n).step_by(Self::BLOCK) {
            let ie = (ib + Self::BLOCK).min(n);
            for jb in (ib..n).step_by(Self::BLOCK) {
                let je = (jb + Self::BLOCK).min(n);
                for i in ib..ie {
                    let ri = kn.row(i);
                    for j in jb.max(i + 1)..je {
                        let d = dot(ri, kn.row(j));
                        w.data[i * n + j] = d;
                        w.data[j * n + i] = d;
                    }
                }
            }
        }
        for i in 0..n {
            w.data[i * n + i] = 1.0;
        }
    }

    /// Token count.
    #[inline]
    pub fn n(&self) -> usize {
        self.w.rows
    }

    /// Cosine similarity between tokens `i` and `j`.
    #[inline]
    pub fn cos(&self, i: usize, j: usize) -> f32 {
        self.w.get(i, j)
    }

    /// Best match for token `a` among the B candidates `b`, skipping
    /// candidates whose token index is below `min_b_idx` (DiffRate uses
    /// this to keep CLS from receiving merges).  Returns the *position in
    /// `b`* of the most similar candidate and its similarity; ties keep
    /// the earliest candidate, matching the plan builders' historical
    /// strict-`>` scan.  `None` when no candidate qualifies.
    pub fn best_match(&self, a: usize, b: &[usize], min_b_idx: usize)
                      -> Option<(usize, f32)> {
        let row = self.w.row(a);
        let mut best: Option<(usize, f32)> = None;
        for (bi, &bidx) in b.iter().enumerate() {
            if bidx < min_b_idx {
                continue;
            }
            let d = row[bidx];
            if best.map_or(true, |(_, bd)| d > bd) {
                best = Some((bi, d));
            }
        }
        best
    }
}

/// C = A @ B (allocating wrapper over [`matmul_into`]).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(0, 0);
    matmul_into(a, b.view(), &mut c);
    c
}

/// C = A @ B into a reusable output buffer (ikj loop with contiguous
/// row-axpy the compiler vectorizes).  `c` is reshaped to `(a.rows,
/// b.cols)` in place — allocation-free once warm.  `a` is anything
/// view-convertible (`&Mat` or a raw [`MatRef`] over caller memory, e.g.
/// a request slice on the serving path).
pub fn matmul_into<'a>(a: impl Into<MatRef<'a>>, b: MatRef, c: &mut Mat) {
    let a: MatRef = a.into();
    assert_eq!(a.cols, b.rows, "matmul inner dim mismatch");
    c.reset(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * b.cols..(kk + 1) * b.cols];
            for (cj, &bv) in crow.iter_mut().zip(brow) {
                *cj += av * bv;
            }
        }
    }
}

/// C = A @ B^T — the similarity-matrix shape; avoids materializing B^T.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dim mismatch");
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        for j in 0..b.rows {
            let brow = b.row(j);
            let mut acc = 0.0f32;
            for k in 0..a.cols {
                acc += arow[k] * brow[k];
            }
            c.data[i * b.rows + j] = acc;
        }
    }
    c
}

/// L2-normalize each row (eps matches the JAX reference).
pub fn normalize_rows(m: &Mat) -> Mat {
    let mut out = Mat::zeros(0, 0);
    normalize_rows_into(m, &mut out);
    out
}

/// L2-normalize each row into a reusable output buffer (the shared-Gram
/// scratch path; numerics identical to [`normalize_rows`]).
pub fn normalize_rows_into(m: &Mat, out: &mut Mat) {
    out.reshape(m.rows, m.cols);
    out.data.copy_from_slice(&m.data);
    for i in 0..m.rows {
        let r = out.row_mut(i);
        let n: f32 = r.iter().map(|v| v * v).sum::<f32>().sqrt() + 1e-6;
        for v in r.iter_mut() {
            *v /= n;
        }
    }
}

/// Pairwise cosine-similarity matrix W (N, N) of row features (one-shot
/// convenience over [`CosineGram::build`]).
pub fn cosine_matrix(kf: &Mat) -> Mat {
    CosineGram::build(kf).w
}

/// Row-wise softmax in place.
pub fn softmax_rows(m: &mut Mat) {
    for i in 0..m.rows {
        let r = m.row_mut(i);
        let mx = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in r.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in r.iter_mut() {
            *v /= sum;
        }
    }
}

/// LayerNorm over the last axis with learned scale/shift (allocating
/// wrapper over [`layernorm_into`]).
pub fn layernorm(x: &Mat, w: &[f32], b: &[f32], eps: f32) -> Mat {
    let mut out = Mat::zeros(0, 0);
    layernorm_into(x, w, b, eps, &mut out);
    out
}

/// LayerNorm into a reusable output buffer — allocation-free once warm.
pub fn layernorm_into(x: &Mat, w: &[f32], b: &[f32], eps: f32, out: &mut Mat) {
    assert_eq!(x.cols, w.len());
    assert_eq!(x.cols, b.len());
    out.reshape(x.rows, x.cols);
    for i in 0..x.rows {
        let r = x.row(i);
        let mu: f32 = r.iter().sum::<f32>() / x.cols as f32;
        let var: f32 = r.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let o = out.row_mut(i);
        for j in 0..x.cols {
            o[j] = (r[j] - mu) * inv * w[j] + b[j];
        }
    }
}

/// tanh-approximation GELU, matching `model.py::gelu`.
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6_f32 * (x + 0.044_715 * x * x * x)).tanh())
}

/// Apply GELU elementwise in place.
pub fn gelu_inplace(m: &mut Mat) {
    for v in m.data.iter_mut() {
        *v = gelu(*v);
    }
}

/// Indices that sort `vals` descending, written into a reusable buffer —
/// allocation-free once `idx` has seen its largest length.
///
/// Ties keep ascending index order (the explicit index tie-break makes the
/// in-place unstable sort reproduce the stable ordering the allocating
/// `sort_by` historically provided, without its merge buffer).
pub fn argsort_desc_into(vals: &[f32], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..vals.len());
    idx.sort_unstable_by(|&a, &b| {
        vals[b].partial_cmp(&vals[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.cmp(&b))
    });
}

/// Indices that sort `vals` ascending into a reusable buffer (ties keep
/// ascending index order; see [`argsort_desc_into`]).
pub fn argsort_asc_into(vals: &[f32], idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..vals.len());
    idx.sort_unstable_by(|&a, &b| {
        vals[a].partial_cmp(&vals[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.cmp(&b))
    });
}

/// Indices that sort `vals` descending (stable ordering; allocating
/// wrapper over [`argsort_desc_into`]).
// lint: allow(alloc) reason=allocating convenience wrapper over argsort_desc_into
pub fn argsort_desc(vals: &[f32]) -> Vec<usize> {
    let mut idx = Vec::new();
    argsort_desc_into(vals, &mut idx);
    idx
}

/// Indices that sort `vals` ascending (stable ordering; allocating
/// wrapper over [`argsort_asc_into`]).
// lint: allow(alloc) reason=allocating convenience wrapper over argsort_asc_into
pub fn argsort_asc(vals: &[f32]) -> Vec<usize> {
    let mut idx = Vec::new();
    argsort_asc_into(vals, &mut idx);
    idx
}

/// argmax over a slice.
pub fn argmax(vals: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in vals.iter().enumerate() {
        if v > vals[best] {
            best = i;
        }
    }
    best
}

/// x @ w + b for a weight matrix (in, out) and bias (out) — allocating
/// wrapper over [`dense_into`].
pub fn dense(x: &Mat, w: &Mat, b: Option<&[f32]>) -> Mat {
    let mut y = Mat::zeros(0, 0);
    dense_into(x, w.view(), b, &mut y);
    y
}

/// x @ w + b into a reusable output buffer — allocation-free once warm.
/// `x` is anything view-convertible, like [`matmul_into`]'s `a`.
pub fn dense_into<'a>(x: impl Into<MatRef<'a>>, w: MatRef, b: Option<&[f32]>,
                      y: &mut Mat) {
    matmul_into(x, w, y);
    if let Some(bias) = b {
        assert_eq!(bias.len(), y.cols);
        for i in 0..y.rows {
            let r = y.row_mut(i);
            for (v, &bv) in r.iter_mut().zip(bias) {
                *v += bv;
            }
        }
    }
}

/// Elementwise a += b.
pub fn add_inplace(a: &mut Mat, b: &Mat) {
    assert_eq!(a.rows, b.rows);
    assert_eq!(a.cols, b.cols);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += *y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = Mat::from_fn(3, 4, |i, j| (i + j) as f32 * 0.5);
        let b = Mat::from_fn(5, 4, |i, j| (i * j) as f32 * 0.25 - 1.0);
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-6);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let mut m = Mat::from_fn(2, 4, |i, j| (i * j) as f32);
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f32 = m.row(i).iter().sum();
            assert!(approx(s, 1.0, 1e-6));
        }
    }

    #[test]
    fn cosine_matrix_diag_is_one() {
        let m = Mat::from_fn(4, 8, |i, j| ((i * 13 + j * 7) % 11) as f32 - 5.0);
        let w = cosine_matrix(&m);
        for i in 0..4 {
            assert!(approx(w.get(i, i), 1.0, 1e-3), "diag {}", w.get(i, i));
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = Mat::from_fn(1, 6, |_, j| j as f32 * 2.0);
        let w = vec![1.0; 6];
        let b = vec![0.0; 6];
        let y = layernorm(&x, &w, &b, 1e-5);
        let mu: f32 = y.row(0).iter().sum::<f32>() / 6.0;
        assert!(approx(mu, 0.0, 1e-5));
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 67] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..len).map(|i| (i as f32 * 0.91).cos()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-4, "len {len}");
        }
    }

    #[test]
    fn dot_lane_widths_agree() {
        // the SIMD-width audit: every cfg-selectable lane count must agree
        // with the scalar reduction (and with each other) to float
        // tolerance, at lengths around every block boundary
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 64, 67] {
            let a: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..len).map(|i| (i as f32 * 0.91).cos()).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let widths = [
                (1usize, dot_with_lanes::<1>(&a, &b)),
                (2, dot_with_lanes::<2>(&a, &b)),
                (4, dot_with_lanes::<4>(&a, &b)),
                (8, dot_with_lanes::<8>(&a, &b)),
                (16, dot_with_lanes::<16>(&a, &b)),
                // odd widths exercise the linear-fold fallback
                (3, dot_with_lanes::<3>(&a, &b)),
                (6, dot_with_lanes::<6>(&a, &b)),
            ];
            for &(w, got) in &widths {
                assert!((got - naive).abs() < 1e-4,
                        "lanes {w} len {len}: {got} vs {naive}");
            }
            // the default entry point is exactly the DOT_LANES instantiation
            assert_eq!(dot(&a, &b), dot_with_lanes::<DOT_LANES>(&a, &b),
                       "len {len}");
        }
    }

    #[test]
    fn dot_default_lane_selection_matches_target() {
        // the cfg ladder must resolve to exactly the width documented for
        // the build target — a cfg typo would silently fall through to the
        // 8-lane default and this is the only place that would notice
        #[cfg(any(target_arch = "aarch64", target_arch = "arm"))]
        assert_eq!(DOT_LANES, 4, "NEON targets select 4 lanes");
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
        assert_eq!(DOT_LANES, 16, "AVX-512 builds select 16 lanes");
        #[cfg(not(any(target_arch = "aarch64", target_arch = "arm",
                      all(target_arch = "x86_64",
                          target_feature = "avx512f"))))]
        assert_eq!(DOT_LANES, 8, "default targets select 8 lanes");
        // and whatever was selected must be bitwise what `dot` computes
        let a: Vec<f32> = (0..67).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..67).map(|i| (i as f32 * 0.91).cos()).collect();
        assert_eq!(dot(&a, &b), dot_with_lanes::<DOT_LANES>(&a, &b));
    }

    #[test]
    fn argsort_into_matches_wrapper_and_reuses_buffer() {
        let vals = [3.0f32, 1.0, 3.0, -2.0, 0.5, 1.0];
        // dirty, oversized buffer: results must still match the wrappers
        let mut idx = vec![99usize; 32];
        argsort_desc_into(&vals, &mut idx);
        assert_eq!(idx, argsort_desc(&vals));
        // ties keep ascending index order (stable semantics)
        assert_eq!(idx, vec![0, 2, 1, 5, 4, 3]);
        argsort_asc_into(&vals, &mut idx);
        assert_eq!(idx, argsort_asc(&vals));
        assert_eq!(idx, vec![3, 4, 1, 5, 0, 2]);
    }

    #[test]
    fn gram_is_symmetric_and_matches_pairwise_dots() {
        let m = Mat::from_fn(37, 19, |i, j| ((i * 13 + j * 7) % 11) as f32 - 5.0);
        let g = CosineGram::build(&m);
        let kn = normalize_rows(&m);
        for i in 0..m.rows {
            for j in 0..m.rows {
                assert_eq!(g.cos(i, j), g.cos(j, i), "asymmetric at {i},{j}");
                if i != j {
                    let want = dot(kn.row(i), kn.row(j));
                    assert!((g.cos(i, j) - want).abs() < 1e-6);
                }
            }
            assert_eq!(g.cos(i, i), 1.0);
        }
    }

    #[test]
    fn normalize_rows_produces_unit_rows() {
        let m = Mat::from_fn(5, 4, |i, j| (i + j) as f32 + 1.0);
        let kn = normalize_rows(&m);
        for i in 0..5 {
            let unit: f32 = kn.row(i).iter().map(|v| v * v).sum();
            assert!((unit - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn gram_build_counter_increments() {
        let before = gram_builds_this_thread();
        let m = Mat::from_fn(6, 4, |i, j| (i * 4 + j) as f32);
        let _ = CosineGram::build(&m);
        assert_eq!(gram_builds_this_thread(), before + 1);
    }

    #[test]
    fn into_ops_match_allocating_ops_and_reuse_buffers() {
        let x = Mat::from_fn(5, 6, |i, j| ((i * 7 + j * 3) % 9) as f32 * 0.25 - 1.0);
        let w = Mat::from_fn(6, 4, |i, j| ((i + 2 * j) % 5) as f32 * 0.5 - 1.0);
        let bias: Vec<f32> = (0..4).map(|j| j as f32 * 0.1).collect();
        let lw = vec![1.1; 6];
        let lb = vec![-0.2; 6];

        // warm buffers at a *larger* shape, then reuse at the real shape:
        // results must match the allocating path exactly
        let mut c = Mat::from_fn(9, 9, |_, _| 7.0);
        matmul_into(&x, w.view(), &mut c);
        assert!(c.max_abs_diff(&matmul(&x, &w)) == 0.0);

        let mut y = Mat::from_fn(9, 9, |_, _| 7.0);
        dense_into(&x, w.view(), Some(&bias), &mut y);
        assert!(y.max_abs_diff(&dense(&x, &w, Some(&bias))) == 0.0);

        let mut ln = Mat::from_fn(9, 9, |_, _| 7.0);
        layernorm_into(&x, &lw, &lb, 1e-5, &mut ln);
        assert!(ln.max_abs_diff(&layernorm(&x, &lw, &lb, 1e-5)) == 0.0);

        let mut nm = Mat::from_fn(9, 9, |_, _| 7.0);
        normalize_rows_into(&x, &mut nm);
        assert!(nm.max_abs_diff(&normalize_rows(&x)) == 0.0);
    }

    #[test]
    fn gram_rebuild_matches_build_and_counts_once() {
        let m1 = Mat::from_fn(23, 9, |i, j| ((i * 13 + j * 7) % 11) as f32 - 5.0);
        let m2 = Mat::from_fn(11, 9, |i, j| ((i * 5 + j * 3) % 7) as f32 - 3.0);
        let mut g = CosineGram::empty();
        let mut kn = Mat::zeros(0, 0);
        // rebuild big, then small: the shrunk reuse must still match build
        for m in [&m1, &m2] {
            let before = gram_builds_this_thread();
            g.rebuild(m, &mut kn);
            assert_eq!(gram_builds_this_thread(), before + 1);
            let want = CosineGram::build(m);
            assert_eq!(g.w.rows, want.w.rows);
            assert!(g.w.max_abs_diff(&want.w) == 0.0);
        }
    }

    #[test]
    fn argsort_desc_orders() {
        assert_eq!(argsort_desc(&[1.0, 3.0, 2.0]), vec![1, 2, 0]);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }
}
