//! Tiny CLI flag parser (clap is unavailable in this environment,
//! DESIGN.md §11).  Supports `--flag`, `--key value`, and positionals.

use std::collections::HashMap;

/// Parsed command line.
pub struct Args {
    /// positional arguments in order
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse() -> Args {
        Self::from_vec(std::env::args().skip(1).collect())
    }

    /// Parse from an explicit vector (tests).
    pub fn from_vec(argv: Vec<String>) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut present = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                present.push(name.to_string());
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags, present }
    }

    /// String flag with default.
    pub fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Whether a bare flag was given.
    pub fn has(&self, name: &str) -> bool {
        self.present.iter().any(|p| p == name || p.starts_with(&format!("{name}=")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::from_vec(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parses_mixed() {
        let a = args("serve --rate 300 --burst --n=5 trace.json");
        assert_eq!(a.positional, vec!["serve", "trace.json"]);
        assert_eq!(a.get_parse::<f64>("rate", 0.0), 300.0);
        assert!(a.has("burst"));
        assert_eq!(a.get_parse::<usize>("n", 0), 5);
        assert!(!a.has("missing"));
        assert_eq!(a.get("missing", "d"), "d");
    }
}
