//! Thread-local allocation counter — the test/bench hook behind the
//! "zero heap allocations in the steady-state encoder loop" guarantee.
//!
//! [`CountingAllocator`] wraps the system allocator and bumps a
//! thread-local counter on every `alloc`/`realloc`/`alloc_zeroed`.  It is
//! **not** installed by the library itself (the counter stays at 0 and
//! costs nothing); binaries that want to measure install it themselves:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: pitome::util::alloc::CountingAllocator =
//!     pitome::util::alloc::CountingAllocator;
//! ```
//!
//! then bracket the region of interest with [`allocs_this_thread`] — see
//! `tests/alloc_free.rs` and `benches/encoder_bench.rs`.  The counter is
//! per-thread, so other threads' allocations never pollute a measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // const-initialized Cell<u64>: no lazy init and no destructor, so the
    // TLS access is safe from inside the allocator itself
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

/// Number of heap allocations this thread has performed since it started
/// (always 0 unless [`CountingAllocator`] is the global allocator).
pub fn allocs_this_thread() -> u64 {
    ALLOC_COUNT.with(|c| c.get())
}

/// System-allocator wrapper that counts allocations per thread.
pub struct CountingAllocator;

// SAFETY: every method defers to `System`, which upholds the GlobalAlloc
// contract; the only extra work is bumping a thread-local counter, which
// cannot itself allocate or unwind.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: caller guarantees `layout` has non-zero size; forwarded
    // verbatim to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    // SAFETY: caller guarantees `ptr` came from this allocator with this
    // `layout`; forwarded verbatim to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: caller guarantees `ptr`/`layout` match a live allocation and
    // `new_size` is non-zero; forwarded verbatim to `System.realloc`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: same contract as `alloc`; `System.alloc_zeroed` returns
    // zeroed memory or null.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_monotonic_and_readable() {
        // the library does not install the allocator, so the counter may
        // simply stay at 0 here — assert the hook is callable and sane
        let a = allocs_this_thread();
        let _v: Vec<u8> = Vec::with_capacity(128);
        let b = allocs_this_thread();
        assert!(b >= a);
    }
}
