//! Minimal JSON parser — the build environment resolves no external JSON
//! crate (DESIGN.md §11), and the runtime only needs to *read* manifests
//! and test vectors written by the Python build path.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (never emitted by our writers).

use std::collections::HashMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// any number (we only need f64 precision)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object
    Obj(HashMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// byte offset in input
    pub at: usize,
    /// description
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { at: self.i, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected {s}"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| JsonError { at: start, msg: "bad utf8 in number".into() })?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { at: start, msg: format!("bad number {s:?}") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(JsonError {
                        at: self.i,
                        msg: "bad escape".into(),
                    })?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("short \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError { at: self.i, msg: "bad \\u".into() })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError { at: self.i, msg: "bad \\u".into() })?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                Some(c) => {
                    // copy one UTF-8 scalar
                    let len = utf8_len(c);
                    let end = (self.i + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[self.i..end])
                            .map_err(|_| JsonError { at: self.i, msg: "bad utf8".into() })?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

impl Json {
    /// Object field accessor.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object accessor.
    pub fn obj(&self) -> Option<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// usize accessor.
    pub fn usize(&self) -> Option<usize> {
        self.num().map(|n| n as usize)
    }

    /// String accessor.
    pub fn str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// f32 vector from a numeric array.
    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        self.arr()
            .map(|a| a.iter().filter_map(|v| v.num()).map(|n| n as f32).collect())
    }

    /// usize vector from a numeric array.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.arr()
            .map(|a| a.iter().filter_map(|v| v.num()).map(|n| n as usize).collect())
    }

    /// Nested f32 matrix (array of arrays) flattened row-major with dims.
    pub fn f32_mat(&self) -> Option<(usize, usize, Vec<f32>)> {
        let rows = self.arr()?;
        let cols = rows.first()?.arr()?.len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            data.extend(r.f32_vec()?);
        }
        Some((rows.len(), cols, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str(),
                   Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn f32_mat_roundtrip() {
        let v = parse("[[1, 2], [3, 4], [5, 6]]").unwrap();
        let (r, c, d) = v.f32_mat().unwrap();
        assert_eq!((r, c), (3, 2));
        assert_eq!(d, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
