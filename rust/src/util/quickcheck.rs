//! Property-testing helper (DESIGN.md §11): seeded random case generation
//! with a fixed case budget — the proptest stand-in used by the invariant
//! tests in `rust/tests/prop_merge.rs`.

use crate::data::Rng;

/// A source of random test inputs.
pub struct Gen {
    /// underlying RNG
    pub rng: Rng,
}

impl Gen {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo as f64, hi as f64) as f32
    }

    /// f32 vector.
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Pick one of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }
}

/// Run `cases` randomized cases of the property; panics with the case
/// number and seed on failure so the case is reproducible.
pub fn property(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!("property {name:?} failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property("count", 25, |_g| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failure() {
        property("fail", 10, |g| {
            let v = g.usize_in(0, 9);
            assert!(v < 5, "boom {v}");
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
