//! In-repo utility substrates that replace unavailable external crates
//! (DESIGN.md §11): JSON parsing, micro-benchmarking, property testing.

pub mod alloc;
pub mod args;
pub mod bench;
pub mod json;
pub mod quickcheck;

pub use alloc::{allocs_this_thread, CountingAllocator};
pub use args::Args;
pub use bench::{smoke, Bench, BenchResult};
pub use json::{parse as parse_json, Json};
pub use quickcheck::{property, Gen};
