//! Criterion-style micro-bench harness (DESIGN.md §11): warmup + sampled
//! timing with mean/p50/p99 reporting.  Used by `rust/benches/*` which run
//! with `harness = false`.

use std::time::{Duration, Instant};

/// True when the bench should run in CI smoke mode (tiny shapes, few
/// samples — just enough to prove the bench still compiles and runs).
/// Enabled by the `BENCH_SMOKE` env var (any value except `0`, the empty
/// string, or `false`) or a `--smoke` CLI argument; the CI workflow runs
/// every bench this way so they cannot bit-rot.
pub fn smoke() -> bool {
    let env_on = match std::env::var("BENCH_SMOKE") {
        Ok(v) => !matches!(v.as_str(), "" | "0" | "false"),
        Err(_) => false,
    };
    env_on || std::env::args().any(|a| a == "--smoke")
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// name
    pub name: String,
    /// samples in nanoseconds
    pub samples_ns: Vec<u64>,
}

impl BenchResult {
    /// mean ns
    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<u64>() as f64 / self.samples_ns.len() as f64
    }

    fn pct(&self, q: f64) -> u64 {
        let mut s = self.samples_ns.clone();
        s.sort_unstable();
        let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
        s[idx]
    }

    /// median ns
    pub fn p50_ns(&self) -> u64 {
        self.pct(0.5)
    }

    /// p99 ns
    pub fn p99_ns(&self) -> u64 {
        self.pct(0.99)
    }

    /// human line
    pub fn report(&self) -> String {
        format!(
            "{:<44} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p50_ns() as f64),
            fmt_ns(self.p99_ns() as f64),
            self.samples_ns.len()
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner.
pub struct Bench {
    /// warmup iterations
    pub warmup: usize,
    /// measured samples
    pub samples: usize,
    /// collected results
    pub results: Vec<BenchResult>,
    /// named scalar metrics (goodput, shed rate, ...) recorded alongside
    /// the timing results and emitted into the JSON output
    pub metrics: Vec<(String, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, samples: 20, results: Vec::new(),
                metrics: Vec::new() }
    }
}

impl Bench {
    /// Runner with explicit sample counts.
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bench { warmup, samples, results: Vec::new(), metrics: Vec::new() }
    }

    /// Record a named scalar metric (printed and included in
    /// [`Bench::write_json`] output).  Non-finite values are clamped to
    /// 0.0 so the hand-rolled JSON stays parseable.
    pub fn metric(&mut self, name: &str, value: f64) {
        let v = if value.is_finite() { value } else { 0.0 };
        println!("{name:<44} = {v:.4}");
        self.metrics.push((name.to_string(), v));
    }

    /// Time `f` and record under `name`. The closure's return value is
    /// black-boxed to keep the optimizer honest.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as u64);
        }
        let r = BenchResult { name: name.into(), samples_ns: samples };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Time a batch-style closure that reports its own work unit count;
    /// prints throughput as well.
    pub fn run_throughput<T>(&mut self, name: &str, units: u64,
                             mut f: impl FnMut() -> T) -> &BenchResult {
        let r = self.run(name, &mut f);
        let per_unit = r.mean_ns() / units as f64;
        let per_sec = 1e9 / per_unit;
        println!("{:<44}   -> {:.1} units/s ({} per unit)", "", per_sec,
                 fmt_ns(per_unit));
        self.results.last().unwrap()
    }

    /// Total wall-clock guard: cap the whole bench with a budget so CI
    /// never hangs (returns false when exceeded).
    pub fn within_budget(&self, started: Instant, budget: Duration) -> bool {
        started.elapsed() < budget
    }

    /// Write every recorded result to `BENCH_<tag>.json` in the current
    /// directory (hand-rolled serialization — no serde dependency):
    /// name, mean/p50/p99 nanoseconds, and sample count per entry.  The
    /// CI bench-smoke step uploads these as workflow artifacts so bench
    /// output is diffable across runs instead of living only in logs.
    /// Write failures are reported but never fail the bench.
    pub fn write_json(&self, tag: &str) {
        let mut s = String::from("{\n  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            let sep = if i + 1 == self.results.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \
                 \"p50_ns\": {}, \"p99_ns\": {}, \"samples\": {}}}{sep}\n",
                json_escape(&r.name), r.mean_ns(), r.p50_ns(), r.p99_ns(),
                r.samples_ns.len()));
        }
        if self.metrics.is_empty() {
            s.push_str("  ]\n}\n");
        } else {
            s.push_str("  ],\n  \"metrics\": {\n");
            for (i, (name, v)) in self.metrics.iter().enumerate() {
                let sep = if i + 1 == self.metrics.len() { "" } else { "," };
                s.push_str(&format!("    \"{}\": {:.4}{sep}\n",
                                    json_escape(name), v));
            }
            s.push_str("  }\n}\n");
        }
        let path = format!("BENCH_{tag}.json");
        match std::fs::write(&path, s) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("[bench] failed to write {path}: {e}"),
        }
    }
}

/// Escape a string for embedding in a JSON string literal (quotes,
/// backslashes, and control characters; bench names contain nothing
/// more exotic).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut b = Bench::new(1, 5);
        b.run("noop", || 1 + 1);
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean_ns() < 1e7);
        assert!(b.results[0].p50_ns() <= b.results[0].p99_ns());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("us"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
    }

    #[test]
    fn metrics_record_and_clamp_nonfinite() {
        let mut b = Bench::new(0, 1);
        b.metric("goodput_rps", 123.4567);
        b.metric("bad_nan", f64::NAN);
        b.metric("bad_inf", f64::INFINITY);
        assert_eq!(b.metrics.len(), 3);
        assert!((b.metrics[0].1 - 123.4567).abs() < 1e-9);
        assert_eq!(b.metrics[1].1, 0.0);
        assert_eq!(b.metrics[2].1, 0.0);
    }

    #[test]
    fn json_escape_handles_quotes_and_controls() {
        assert_eq!(json_escape(r#"a "b" c"#), r#"a \"b\" c"#);
        assert_eq!(json_escape("back\\slash"), "back\\\\slash");
        assert_eq!(json_escape("line\nbreak\ttab"), "line\\nbreak\\ttab");
        assert_eq!(json_escape("bell\u{7}"), "bell\\u0007");
        assert_eq!(json_escape("plain"), "plain");
    }
}
