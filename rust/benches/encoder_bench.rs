//! PERF/L3 — encoder forward benchmarks: the scratch-workspace forward vs
//! the seed's allocating scalar attention, the per-layer
//! attention/merge/MLP split, allocations-per-forward, and
//! allocations-per-request on the engine serving path (via the
//! thread-local [`CountingAllocator`] hook).
//! (Custom harness; criterion unavailable — DESIGN.md §11.  Run with
//! `BENCH_SMOKE=1` / `--smoke` for the tiny CI shapes.)

use pitome::config::{ViTConfig, DEFAULT_TOFU_PRUNE_THRESHOLD};
use pitome::data::Rng;
use pitome::engine::Engine;
use pitome::merge::{merge_step_scratch, MergeCtx, MergeMode, MergeScratch};
use pitome::model::{attention_into, encoder_forward, encoder_layers,
                    synthetic_vit_store, EncoderCfg, EncoderScratch,
                    ParamStore, ResolvedEncoder};
use pitome::tensor::{dense_into, gelu_inplace, softmax_rows, Mat};
use pitome::util::{allocs_this_thread, smoke, Bench, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// The seed's attention (verbatim pre-scratch implementation): scalar
/// triple-loop scores and a fresh (n, n) score matrix allocated per head.
/// Kept here as the baseline the vectorized head-blocked kernel is
/// measured against.
fn seed_attention(q: &Mat, kf: &Mat, v: &Mat, sizes: &[f32], heads: usize,
                  prop_attn: bool) -> (Mat, Vec<f32>) {
    let n = q.rows;
    let dim = q.cols;
    let d = dim / heads;
    let scale = 1.0 / (d as f32).sqrt();
    let log_m: Vec<f32> = if prop_attn {
        sizes.iter().map(|&s| s.max(1e-9).ln()).collect()
    } else {
        vec![0.0; n]
    };
    let mut out = Mat::zeros(n, dim);
    let mut attn_cls = vec![0f32; n];
    for hh in 0..heads {
        let col0 = hh * d;
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            let qi = &q.row(i)[col0..col0 + d];
            for j in 0..n {
                let kj = &kf.row(j)[col0..col0 + d];
                let mut dot = 0f32;
                for c in 0..d {
                    dot += qi[c] * kj[c];
                }
                s.set(i, j, dot * scale + log_m[j]);
            }
        }
        {
            let mut row0 = vec![0f32; n];
            for j in 0..n {
                row0[j] = s.get(0, j) - log_m[j];
            }
            let mx = row0.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for vj in row0.iter_mut() {
                *vj = (*vj - mx).exp();
                sum += *vj;
            }
            for (a, vj) in attn_cls.iter_mut().zip(&row0) {
                *a += vj / sum / heads as f32;
            }
        }
        softmax_rows(&mut s);
        for i in 0..n {
            let orow = out.row_mut(i);
            for j in 0..n {
                let p = s.get(i, j);
                if p == 0.0 {
                    continue;
                }
                let vj = &v.row(j)[col0..col0 + d];
                for c in 0..d {
                    orow[col0 + c] += p * vj[c];
                }
            }
        }
    }
    (out, attn_cls)
}

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32)
}

fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 3) } else { Bench::new(3, 15) };
    println!("# encoder forward benchmarks (scratch workspace){}",
             if sm { " [smoke]" } else { "" });

    // --- attention kernel: seed scalar loop vs head-blocked 8-lane dot
    let (n, dim, heads) = if sm { (33usize, 64usize, 4usize) } else { (197, 384, 6) };
    let mut rng = Rng::new(1);
    let q = random_mat(&mut rng, n, dim);
    let kf = random_mat(&mut rng, n, dim);
    let v = random_mat(&mut rng, n, dim);
    let sizes = vec![1.0f32; n];
    b.run(&format!("attention seed-alloc n={n} dim={dim} h={heads}"), || {
        seed_attention(&q, &kf, &v, &sizes, heads, true)
    });
    let mut ktile = Mat::zeros(0, 0);
    let mut scores = Mat::zeros(0, 0);
    let mut attn_out = Mat::zeros(0, 0);
    let mut attn_cls = Vec::new();
    let mut log_m = Vec::new();
    let mut row0 = Vec::new();
    b.run(&format!("attention scratch    n={n} dim={dim} h={heads}"), || {
        attention_into(&q, &kf, &v, &sizes, heads, true, &mut ktile,
                       &mut scores, &mut attn_out, &mut attn_cls, &mut log_m,
                       &mut row0);
    });
    let seed_p50 = b.results[b.results.len() - 2].p50_ns() as f64;
    let scratch_p50 = b.results[b.results.len() - 1].p50_ns() as f64;
    println!("attention speedup scratch vs seed (p50): {:.2}x \
              (acceptance floor: 2x)\n", seed_p50 / scratch_p50);

    // --- per-layer split at the same shape: attention / merge / MLP
    let x = random_mat(&mut rng, n, dim);
    let attn_scores: Vec<f32> = (0..n).map(|i| (i % 13) as f32 * 0.01).collect();
    let k = (n - 1) / 10;
    let mut ms = MergeScratch::new();
    b.run(&format!("layer split: merge pitome n={n} k={k}"), || {
        let mut r = Rng::new(9);
        let ctx = MergeCtx {
            x: &x, kf: &kf, sizes: &sizes, attn_cls: &attn_scores,
            margin: 0.45, k, protect_first: 1,
            tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
        };
        merge_step_scratch(MergeMode::PiToMe, &ctx, &mut r, &mut ms);
    });
    let hidden_dim = dim * 4;
    let w1 = random_mat(&mut rng, dim, hidden_dim);
    let b1 = vec![0.01f32; hidden_dim];
    let w2 = random_mat(&mut rng, hidden_dim, dim);
    let b2 = vec![0.01f32; dim];
    let mut hidden = Mat::zeros(0, 0);
    let mut mlp_out = Mat::zeros(0, 0);
    b.run(&format!("layer split: mlp n={n} dim={dim} hidden={hidden_dim}"), || {
        dense_into(&x, w1.view(), Some(&b1), &mut hidden);
        gelu_inplace(&mut hidden);
        dense_into(&hidden, w2.view(), Some(&b2), &mut mlp_out);
    });

    // --- full serial forward: transient vs reused scratch
    let vcfg = if sm {
        ViTConfig { merge_mode: "pitome".into(), merge_r: 0.9,
                    ..Default::default() }
    } else {
        let mut c = ViTConfig::preset("deit-s").unwrap();
        c.merge_mode = "pitome".into();
        c.merge_r = 0.9;
        c
    };
    let ps = synthetic_vit_store(&vcfg, 7);
    let cfg = EncoderCfg::from_vit(&vcfg);
    let n0 = cfg.plan[0];
    let x0 = random_mat(&mut rng, n0, cfg.dim);
    b.run(&format!("forward one-shot          {} d={}", vcfg.name, cfg.depth), || {
        let mut r = Rng::new(0);
        encoder_forward(&ps, &cfg, x0.clone(), &mut r).unwrap()
    });
    let engine = Engine::from_store(synthetic_vit_store(&vcfg, 7));
    let mut sess = engine.session(cfg.clone()).unwrap();
    b.run(&format!("forward engine session    {} d={}", vcfg.name, cfg.depth), || {
        let mut r = Rng::new(0);
        sess.forward_one(&x0, &mut r).unwrap();
    });

    // --- allocations per steady-state layer loop (the alloc-counter hook)
    let mut scratch = EncoderScratch::new();
    let re = ResolvedEncoder::new(&ps, &cfg).unwrap();
    let pitome_allocs = count_layer_loop(&ps, &cfg, &re, &mut scratch, &x0);
    let mut none_cfg = cfg.clone();
    none_cfg.mode = MergeMode::None;
    none_cfg.plan = vec![n0; cfg.depth + 1];
    let re_none = ResolvedEncoder::new(&ps, &none_cfg).unwrap();
    let mut none_scratch = EncoderScratch::new();
    let none_allocs = count_layer_loop(&ps, &none_cfg, &re_none,
                                       &mut none_scratch, &x0);
    println!("\nallocations per steady-state layer loop: \
              {none_allocs} (merge off — acceptance: 0), \
              {pitome_allocs} (pitome — acceptance: 0, in-place plans)");
    assert_eq!(none_allocs, 0, "merge-free layer loop must not allocate");
    assert_eq!(pitome_allocs, 0,
               "pitome layer loop must not allocate (in-place plan builders)");

    // --- allocations per request on the engine serving path: raw patch
    // bytes in -> pooled logits out, exactly what a warmed CPU serving
    // worker does per request (outputs included, not just the layer loop)
    let serve_vcfg = ViTConfig {
        merge_mode: "pitome".into(),
        merge_r: 0.9,
        ..Default::default()
    };
    let serve_engine = Engine::from_store(synthetic_vit_store(&serve_vcfg, 7));
    let mut vit = serve_engine.vit_session(&serve_vcfg).unwrap();
    let mut rr = Rng::new(5);
    let raw: Vec<f32> = (0..serve_vcfg.num_patches() * serve_vcfg.patch_dim())
        .map(|_| (rr.next_f64() * 0.2 - 0.1) as f32)
        .collect();
    let request = |vit: &mut pitome::engine::VitSession| {
        vit.begin(1);
        vit.set_patches_slice(0, &raw).unwrap();
        vit.forward(0).unwrap();
        vit.logits(0)[0]
    };
    request(&mut vit); // warm every pool
    let before = allocs_this_thread();
    let iters = 16u64;
    for _ in 0..iters {
        std::hint::black_box(request(&mut vit));
    }
    let per_request = (allocs_this_thread() - before) as f64 / iters as f64;
    b.run("engine serving request (warm)", || request(&mut vit));
    println!("\nallocations per warmed serving request (engine path): \
              {per_request} (acceptance: 0)");
    assert_eq!(per_request, 0.0,
               "warmed engine serving request must not allocate");

    b.write_json("encoder");
}

/// Warm `scratch` with one pass, then count allocations over a second,
/// steady-state pass of the encoder layer loop.
fn count_layer_loop(ps: &ParamStore, cfg: &EncoderCfg, re: &ResolvedEncoder,
                    scratch: &mut EncoderScratch, x0: &Mat) -> u64 {
    let n0 = x0.rows;
    for pass in 0..2 {
        let mut x = x0.clone();
        let mut szs = vec![1.0f32; n0];
        let mut r = Rng::new(0);
        let before = allocs_this_thread();
        encoder_layers(ps, re, cfg, &mut x, &mut szs, &mut r, scratch);
        if pass == 1 {
            return allocs_this_thread() - before;
        }
    }
    unreachable!()
}
