//! PERF/L3 — merge-engine micro-benchmarks: the shared cosine Gram,
//! energy score, each merge algorithm (one Gram per step), and batched
//! merge throughput across worker threads.
//! (Custom harness; criterion unavailable — DESIGN.md §11.)

use pitome::config::DEFAULT_TOFU_PRUNE_THRESHOLD;
use pitome::data::Rng;
use pitome::merge::batch::{merge_step_batch, recommended_workers, BatchSeq};
use pitome::merge::{energy_from_gram, energy_scores, merge_step, MergeCtx,
                    MergeMode};
use pitome::tensor::{CosineGram, Mat};
use pitome::util::{smoke, Bench};

fn random_tokens(n: usize, h: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, h, |_, _| (rng.next_f64() * 2.0 - 1.0) as f32)
}

// lint: allow(one-gram) reason=bench rebuilds the Gram per timed iteration by design
fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 3) } else { Bench::new(3, 15) };
    println!("# merge engine micro-benchmarks (per-sample, single thread){}",
             if sm { " [smoke]" } else { "" });

    let gram_shapes: &[(usize, usize)] = if sm {
        &[(33, 16)]
    } else {
        &[(65, 64), (197, 64), (197, 192), (577, 192)]
    };
    for &(n, h) in gram_shapes {
        let kf = random_tokens(n, h, 1);
        b.run(&format!("energy_scores n={n} h={h}"), || {
            energy_scores(&kf, 0.45)
        });
        // the shared-Gram split: build once, score from the Gram
        b.run(&format!("gram_build    n={n} h={h}"), || CosineGram::build(&kf));
        let g = CosineGram::build(&kf);
        b.run(&format!("energy_from_gram n={n} h={h}"), || {
            energy_from_gram(&g, 0.45)
        });
    }

    let (n, h, k) = if sm { (33usize, 16usize, 4usize) } else { (197, 64, 20) };
    let kf = random_tokens(n, h, 2);
    let x = random_tokens(n, h, 3);
    let sizes = vec![1.0f32; n];
    let attn: Vec<f32> = (0..n).map(|i| (i % 13) as f32 * 0.01).collect();
    for mode in [MergeMode::PiToMe, MergeMode::ToMe, MergeMode::ToFu,
                 MergeMode::Dct, MergeMode::DiffRate, MergeMode::Random] {
        b.run(&format!("merge_step {:10} n={n} k={k}", mode.name()), || {
            let mut rng = Rng::new(9);
            let ctx = MergeCtx { x: &x, kf: &kf, sizes: &sizes,
                                 attn_cls: &attn, margin: 0.45, k,
                                 protect_first: 1,
                                 tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD };
            merge_step(mode, &ctx, &mut rng)
        });
    }

    // batched merging across sequences (the serving path): B sequences per
    // call, fanned out over the available worker threads
    let batch_n = 8usize;
    let workers = recommended_workers();
    let mats: Vec<(Mat, Mat)> = (0..batch_n as u64)
        .map(|i| (random_tokens(n, h, 30 + i), random_tokens(n, h, 40 + i)))
        .collect();
    for w in [1usize, workers] {
        b.run_throughput(
            &format!("merge_batch pitome B={batch_n} workers={w}"),
            batch_n as u64,
            || {
                let seqs: Vec<BatchSeq> = mats.iter().enumerate()
                    .map(|(i, (xb, kb))| BatchSeq {
                        ctx: MergeCtx {
                            x: xb, kf: kb, sizes: &sizes, attn_cls: &attn,
                            margin: 0.45, k, protect_first: 1,
                            tofu_threshold: DEFAULT_TOFU_PRUNE_THRESHOLD,
                        },
                        seed: i as u64,
                    })
                    .collect();
                merge_step_batch(MergeMode::PiToMe, &seqs, w)
            });
    }

    // paper claim: PiToMe within a few ms of ToMe — report the ratio
    // (p50: robust to background-load noise)
    let pitome = b.results.iter()
        .find(|r| r.name.contains("step pitome")).unwrap();
    let tome = b.results.iter()
        .find(|r| r.name.contains("step tome")).unwrap();
    let ratio = pitome.p50_ns() as f64 / tome.p50_ns() as f64;
    println!("\npitome/tome runtime ratio (p50) at n={n}: {ratio:.2}x \
              (paper: comparable; scoring and matching share one Gram)");

    b.write_json("merge");
}
