//! Serving load-harness bench: replays deterministic multi-workload
//! traces end-to-end against `boot_cpu_workloads` through the
//! admission-controlled submit path (ROADMAP item 4).
//!
//! Phases:
//!   1. closed-loop capacity probe (fixed user population → goodput is
//!      the coordinator's sustainable rate);
//!   2. steady open-loop replay at 0.5x capacity with diurnal + mild
//!      burst modulation (healthy regime: no shedding expected);
//!   3. deliberate 2x-overload bursty replay with per-request deadlines
//!      (shed rate must go positive while admitted-request percentiles
//!      stay bounded — no `u64::MAX` sentinels anywhere);
//!   4. an unpaced spike (submission is microseconds, service is
//!      milliseconds) — the worst-case admission-control stress;
//!   5. tracing-overhead probe: the identical closed-loop replay with
//!      the span rings off vs on — recording is a few relaxed atomic
//!      stores per request, so the goodput cost must stay within 2%.
//!
//! Every phase's goodput, shed rate, and per-workload p50/p99/p999 and
//! queue-depth stats land in `BENCH_serving.json` via `Bench::write_json`
//! so scaling progress is measurable PR-over-PR.

use std::sync::Arc;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{run_load, Coordinator, CpuWorkloads, LoadOptions,
                          LoadReport};
use pitome::data::{ArrivalModel, TraceConfig, WorkloadMix};
use pitome::engine::JointKind;
use pitome::model::synthetic_mm_store;
use pitome::util::{smoke, Bench};

/// Boot the multi-workload CPU coordinator the trace replays against:
/// a 3-rung vision ladder (so Balanced routing has somewhere to shed),
/// single-rung text and joint pools, small queues (capacity 8) so
/// overload actually exercises admission control.  `trace_capacity`
/// sizes the per-worker span rings (0 = tracing off).
fn boot(trace_capacity: usize) -> Coordinator {
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        vision: vec![("vit".to_string(),
                      vec![("none".to_string(), 1.0),
                           ("pitome".to_string(), 0.9),
                           ("tome".to_string(), 0.5)])],
        text: vec![("bert".to_string(), vec![("none".to_string(), 1.0)])],
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let cfg = ServingConfig {
        max_batch: 4,
        batch_timeout_us: 500,
        queue_capacity: 8,
        workers: 1,
        trace_capacity,
    };
    Coordinator::boot_cpu_workloads(&ps, &workloads, cfg).expect("boot")
}

/// Closed-loop options: `users` in flight per workload, balanced mix.
fn closed(count: usize, users: usize, seed: u64) -> LoadOptions {
    LoadOptions {
        trace: TraceConfig {
            count,
            mix: WorkloadMix::balanced(),
            arrival: ArrivalModel::Closed { users, think_time_us: 0 },
            seed,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Record one phase's metrics and pin the "no sentinel" acceptance:
/// every reported percentile is clamped to the observed max, never the
/// open top bucket's `u64::MAX` bound.
fn record(b: &mut Bench, tag: &str, rep: &LoadReport) {
    b.metric(&format!("{tag}.goodput_rps"), rep.goodput_rps());
    b.metric(&format!("{tag}.shed_rate"), rep.shed_rate());
    b.metric(&format!("{tag}.offered"), rep.offered() as f64);
    b.metric(&format!("{tag}.shed"), rep.shed() as f64);
    b.metric(&format!("{tag}.expired"), rep.expired() as f64);
    for w in &rep.per_workload {
        let name = w.workload.name();
        assert!(w.latency.p99_us <= w.latency.max_us.max(1),
                "{tag}/{name}: p99 {} exceeds observed max {}",
                w.latency.p99_us, w.latency.max_us);
        assert!(w.latency.p999_us < u64::MAX / 2,
                "{tag}/{name}: unclamped sentinel leaked into p999");
        b.metric(&format!("{tag}.{name}.p50_us"), w.latency.p50_us as f64);
        b.metric(&format!("{tag}.{name}.p99_us"), w.latency.p99_us as f64);
        b.metric(&format!("{tag}.{name}.p999_us"),
                 w.latency.p999_us as f64);
        b.metric(&format!("{tag}.{name}.depth_max"), w.depth_max as f64);
        b.metric(&format!("{tag}.{name}.depth_mean"), w.depth_mean);
    }
}

fn main() {
    let sm = smoke();
    let mut b = Bench::new(0, 1);
    println!("# serving load harness: closed-loop probe + open-loop \
              replay{}", if sm { " [smoke]" } else { "" });
    let coord = boot(0);

    // warmup: fill session scratch and pool freelists outside the
    // measured phases
    let warm = run_load(&coord, &closed(12, 4, 5)).expect("warmup");
    assert_eq!(warm.offered(), 12);

    // phase 1: closed-loop capacity probe
    let probe_n = if sm { 36 } else { 240 };
    println!("\n# phase 1: closed-loop capacity probe ({probe_n} requests)");
    let probe = run_load(&coord, &closed(probe_n, 8, 6)).expect("probe");
    probe.print();
    let cap_rps = probe.goodput_rps().max(1.0);
    b.metric("probe.capacity_rps", cap_rps);
    record(&mut b, "probe", &probe);

    // phase 2: steady open loop at half capacity, diurnal + mild bursts
    let steady_n = if sm { 60 } else { 480 };
    println!("\n# phase 2: steady open loop at 0.5x capacity \
              ({steady_n} requests)");
    let steady = run_load(&coord, &LoadOptions {
        trace: TraceConfig {
            rate: cap_rps * 0.5,
            count: steady_n,
            burstiness: 0.5,
            diurnal: 0.3,
            diurnal_period_s: 2.0,
            mix: WorkloadMix::balanced(),
            seed: 7,
            ..Default::default()
        },
        ..Default::default()
    }).expect("steady");
    steady.print();
    record(&mut b, "steady", &steady);

    // deadline for the overload phases: generous against the healthy
    // p50, so only genuine overload queueing expires requests
    let p50_max = steady
        .per_workload
        .iter()
        .map(|w| w.latency.p50_us)
        .max()
        .unwrap_or(0);
    let deadline_us = (p50_max * 20).max(5_000);
    b.metric("overload.deadline_us", deadline_us as f64);

    // phase 3: deliberate 2x overload, heavy bursts, deadlines armed
    let over_n = if sm { 160 } else { 640 };
    println!("\n# phase 3: 2x overload, bursty, deadline {deadline_us} us \
              ({over_n} requests)");
    let over = run_load(&coord, &LoadOptions {
        trace: TraceConfig {
            rate: cap_rps * 2.0,
            count: over_n,
            burstiness: 1.0,
            mix: WorkloadMix::balanced(),
            deadline_us,
            seed: 8,
            ..Default::default()
        },
        ..Default::default()
    }).expect("overload");
    over.print();
    record(&mut b, "overload", &over);

    // phase 4: unpaced spike — every request submitted immediately
    let spike_n = if sm { 64 } else { 192 };
    println!("\n# phase 4: unpaced spike ({spike_n} requests at once)");
    let spike = run_load(&coord, &LoadOptions {
        trace: TraceConfig {
            count: spike_n,
            mix: WorkloadMix::balanced(),
            deadline_us,
            seed: 9,
            ..Default::default()
        },
        time_scale: 0.0,
        ..Default::default()
    }).expect("spike");
    spike.print();
    record(&mut b, "spike", &spike);

    // the overload acceptance: deliberate 2x overload + spike must shed
    // or expire (capacity-8 queues cannot absorb them), while the
    // percentile assertions in record() pin admitted p99 to bounded,
    // sentinel-free values
    let dropped =
        over.shed() + over.expired() + spike.shed() + spike.expired();
    assert!(dropped > 0,
            "2x overload + unpaced spike against capacity-8 queues must \
             shed or expire requests");
    b.metric("overload.dropped_total", dropped as f64);

    // phase 5: tracing overhead — the same closed-loop replay against a
    // traced and an untraced coordinator.  Best-of-3 goodput per arm
    // damps scheduler noise; the rings are preallocated at boot and a
    // recorded span is a handful of relaxed atomic stores, so the
    // budget is 2% (relaxed in smoke runs, where a few dozen requests
    // cannot resolve that tightly).
    let trace_n = if sm { 48 } else { 320 };
    println!("\n# phase 5: tracing overhead (closed loop, \
              {trace_n} requests per arm, best of 3)");
    let mut best = [0f64; 2]; // [off, on]
    for round in 0u64..3 {
        for (arm, cap) in [0usize, 4096].into_iter().enumerate() {
            let c = boot(cap);
            run_load(&c, &closed(12, 4, 5)).expect("trace warmup");
            let rep = run_load(&c, &closed(trace_n, 8, 20 + round))
                .expect("trace arm");
            assert_eq!(rep.offered() as usize, trace_n);
            best[arm] = best[arm].max(rep.goodput_rps());
        }
    }
    let overhead_pct = ((best[0] - best[1]) / best[0] * 100.0).max(0.0);
    println!("  tracing off {:.1} rps, on {:.1} rps -> overhead {:.2}%",
             best[0], best[1], overhead_pct);
    b.metric("trace.goodput_off_rps", best[0]);
    b.metric("trace.goodput_on_rps", best[1]);
    b.metric("trace.overhead_pct", overhead_pct);
    let budget_pct = if sm { 10.0 } else { 2.0 };
    assert!(overhead_pct <= budget_pct,
            "span tracing cost {overhead_pct:.2}% exceeds the \
             {budget_pct}% budget ({:.1} rps off vs {:.1} rps on)",
            best[0], best[1]);

    b.write_json("serving");
}
