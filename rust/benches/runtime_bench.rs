//! PERF — PJRT runtime benchmarks over the real artifacts: compile time,
//! single-sample latency, batched throughput, compressed vs uncompressed
//! (the measured half of Table 5).  Skips gracefully when artifacts are
//! missing.

use pitome::data::{patchify, shape_item, TEST_SEED};
use pitome::runtime::{load_flat_params, Engine, HostTensor, Registry};
use pitome::util::{smoke, Bench};

fn main() {
    let sm = smoke();
    let dir = Registry::default_dir();
    let reg = match Registry::load(&dir) {
        Ok(r) => r,
        Err(e) => {
            println!("(runtime bench skipped: {e})");
            return;
        }
    };
    let engine = Engine::cpu().expect("PJRT cpu client");
    let mut b = if sm { Bench::new(1, 2) } else { Bench::new(2, 10) };
    println!("# PJRT runtime benchmarks{}", if sm { " [smoke]" } else { "" });

    let artifacts: &[&str] = if sm {
        &["vit_none_b1"]
    } else {
        &["vit_none_b1", "vit_pitome_r900_b1", "vit_none_b8",
          "vit_pitome_r900_b8"]
    };
    for &name in artifacts {
        if reg.get(name).is_err() {
            println!("(skipping {name}: not built)");
            continue;
        }
        let t0 = std::time::Instant::now();
        let exe = engine.load(&reg, name).unwrap();
        println!("compile {name}: {:.2?}", t0.elapsed());
        let params = load_flat_params(
            &dir, exe.entry.meta.params.as_deref().unwrap()).unwrap();
        let batch = exe.entry.meta.batch;
        let mut xdata = Vec::with_capacity(batch * 64 * 16);
        for i in 0..batch {
            let item = shape_item(TEST_SEED, i as u64);
            xdata.extend_from_slice(&patchify(&item.image, 4).data);
        }
        let psize = params.len();
        b.run_throughput(&format!("execute {name}"), batch as u64, || {
            exe.run(&[
                HostTensor::F32(params.clone(), vec![psize]),
                HostTensor::F32(xdata.clone(), vec![batch, 64, 16]),
            ]).unwrap()
        });
    }

    // headline ratio: compressed vs uncompressed throughput at batch 8
    let get = |tag: &str| b.results.iter()
        .find(|r| r.name.contains(tag)).map(|r| r.mean_ns());
    if let (Some(none), Some(pit)) = (get("vit_none_b8"), get("vit_pitome_r900_b8")) {
        println!("\nPJRT speedup pitome r=0.9 vs none (batch 8): {:.2}x \
                  (paper shape: >1x, FLOPs bound {:.2}x)",
                 none / pit, 65f64.powi(2) / 47f64.powi(2));
    }

    b.write_json("runtime");
}
