//! PERF/L3 — coordinator hot-path benchmarks without PJRT: queue
//! round-trip latency, batcher aggregation, metrics overhead, and the
//! typed-router section (per-workload queue depth, joint-batch split
//! overhead, response-recycle hit rate).  These keep the L3 overhead
//! honest against the paper's "merging overhead must not eat the
//! savings" requirement.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{Coordinator, CpuWorkloads, Metrics, Payload, Qos,
                          Workload};
use pitome::data::{generate_trace, patchify, sent_item, shape_item,
                   vqa_item, TraceConfig, TEST_SEED};
use pitome::engine::JointKind;
use pitome::model::synthetic_mm_store;
use pitome::util::{smoke, Bench};

fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 3) } else { Bench::new(3, 15) };
    println!("# coordinator micro-benchmarks (no PJRT){}",
             if sm { " [smoke]" } else { "" });
    let reps: u64 = if sm { 100 } else { 10_000 };
    let msgs: u64 = if sm { 50 } else { 1_000 };

    // metrics overhead on the hot path
    let m = Metrics::default();
    b.run_throughput(&format!("metrics.record x{reps}"), reps, || {
        for i in 0..reps {
            m.record(i % 5_000);
        }
    });

    // channel round trip (the submit/response path minus execution)
    b.run_throughput(&format!("sync_channel round-trip x{msgs}"), msgs, || {
        let (tx, rx) = mpsc::sync_channel::<u64>(1024);
        let j = std::thread::spawn(move || {
            let mut acc = 0u64;
            while let Ok(v) = rx.recv() {
                acc += v;
            }
            acc
        });
        for i in 0..msgs {
            tx.send(i).unwrap();
        }
        drop(tx);
        j.join().unwrap()
    });

    // trace generation cost (excluded from serving numbers)
    b.run(&format!("generate_trace {reps} events"), || {
        generate_trace(&TraceConfig { count: reps as usize,
                                      ..Default::default() })
    });

    // batch assembly: stack 8 x (64x16) f32 inputs (what run_batch does)
    let sample: Vec<f32> = (0..64 * 16).map(|i| i as f32).collect();
    b.run_throughput("batch assembly 8x(64x16)", 8, || {
        let mut data = Vec::with_capacity(8 * sample.len());
        for _ in 0..8 {
            data.extend_from_slice(&sample);
        }
        data
    });

    router_section(sm);

    let t0 = Instant::now();
    let _ = t0.elapsed();
}

/// Typed-router serving section: boots the CPU multi-workload
/// coordinator on synthetic multimodal weights and reports per-workload
/// latency, queue depth, joint-batch split overhead (a paired batch vs
/// its two single-tower halves), and the response-recycle hit rate.
fn router_section(sm: bool) {
    println!("\n# typed router (vision + text + joint pools, synthetic weights)");
    let reqs: usize = if sm { 12 } else { 120 };
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        vision: vec![("vit".to_string(),
                      vec![("pitome".to_string(), 0.9)])],
        text: vec![("bert".to_string(), vec![("none".to_string(), 1.0)])],
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
    };
    let coord = Coordinator::boot_cpu_workloads(
        &ps, &workloads, ServingConfig::default()).expect("boot");
    let pool = coord.pool().clone();
    let slot = coord.response_slot();
    let tcfg = pitome::config::TextConfig::default();

    let item = shape_item(TEST_SEED, 0);
    let patches = patchify(&item.image, 4);
    let (question, _) = vqa_item(TEST_SEED, 0);
    let (tokens, _) = sent_item(TEST_SEED, 0, tcfg.seq_len, 16);

    let submit_vision = |i: u64| {
        let _ = i;
        let mut vt = pool.take_f32(patches.data.len());
        vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        coord.submit_pooled(Workload::Vision, "vit", Qos::Throughput,
                            Payload::Vision(vt), &slot).expect("submit");
        slot.recv().expect("vision response")
    };
    let submit_text = || {
        let mut tt = pool.take_i32(tokens.len());
        tt.fill_i32(&tokens, &[tokens.len()]);
        coord.submit_pooled(Workload::Text, "bert", Qos::Throughput,
                            Payload::Text(tt), &slot).expect("submit");
        slot.recv().expect("text response")
    };
    let submit_joint = || {
        let mut vt = pool.take_f32(patches.data.len());
        vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        let mut qt = pool.take_i32(question.len());
        qt.fill_i32(&question, &[question.len()]);
        coord.submit_pooled(Workload::Joint, "vqa", Qos::Throughput,
                            Payload::Joint { vision: vt, text: qt }, &slot)
            .expect("submit");
        slot.recv().expect("joint response")
    };

    // warm every pool (sessions grow their buffers, freelists fill)
    for i in 0..3 {
        drop(submit_vision(i));
        drop(submit_text());
        drop(submit_joint());
    }

    // per-workload round-trip latency; the joint-vs-halves gap is the
    // split overhead (pair batches run both towers + fusion)
    let lat = |label: &str, f: &mut dyn FnMut()| {
        let t0 = Instant::now();
        for _ in 0..reqs {
            f();
        }
        let us = t0.elapsed().as_micros() as f64 / reqs as f64;
        println!("  {label:<28} {us:>10.1} us/req");
        us
    };
    let v_us = lat("vision round-trip", &mut || drop(submit_vision(1)));
    let t_us = lat("text round-trip", &mut || drop(submit_text()));
    let j_us = lat("joint (pair) round-trip", &mut || drop(submit_joint()));
    println!("  joint split overhead: {:.1} us vs vision+text {:.1} us \
              (x{:.2})",
             j_us, v_us + t_us, j_us / (v_us + t_us).max(1.0));

    // per-workload queue depth (all zero once drained — the admission
    // signal the balanced router sheds on)
    for (w, model, artifact, depth) in coord.router().queue_depths() {
        println!("  depth {:<8} {model}/{artifact}: {depth}", w.name());
    }
    println!("  recycle hit rate: {}", pool.hit_rate_summary());
    let total: u64 = coord.metrics().iter().map(|(_, _, s)| s.count).sum();
    assert_eq!(total as usize, 3 * (reqs + 3), "router lost requests");
}
