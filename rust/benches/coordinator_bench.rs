//! PERF/L3 — coordinator hot-path benchmarks without PJRT: queue
//! round-trip latency, batcher aggregation, metrics overhead.  These keep
//! the L3 overhead honest against the paper's "merging overhead must not
//! eat the savings" requirement.

use std::sync::mpsc;
use std::time::Instant;

use pitome::coordinator::Metrics;
use pitome::data::{generate_trace, TraceConfig};
use pitome::util::Bench;

fn main() {
    let mut b = Bench::new(3, 15);
    println!("# coordinator micro-benchmarks (no PJRT)");

    // metrics overhead on the hot path
    let m = Metrics::default();
    b.run_throughput("metrics.record x10k", 10_000, || {
        for i in 0..10_000u64 {
            m.record(i % 5_000);
        }
    });

    // channel round trip (the submit/response path minus execution)
    b.run_throughput("sync_channel round-trip x1k", 1_000, || {
        let (tx, rx) = mpsc::sync_channel::<u64>(1024);
        let j = std::thread::spawn(move || {
            let mut acc = 0u64;
            while let Ok(v) = rx.recv() {
                acc += v;
            }
            acc
        });
        for i in 0..1_000 {
            tx.send(i).unwrap();
        }
        drop(tx);
        j.join().unwrap()
    });

    // trace generation cost (excluded from serving numbers)
    b.run("generate_trace 10k events", || {
        generate_trace(&TraceConfig { count: 10_000, ..Default::default() })
    });

    // batch assembly: stack 8 x (64x16) f32 inputs (what run_batch does)
    let sample: Vec<f32> = (0..64 * 16).map(|i| i as f32).collect();
    b.run_throughput("batch assembly 8x(64x16)", 8, || {
        let mut data = Vec::with_capacity(8 * sample.len());
        for _ in 0..8 {
            data.extend_from_slice(&sample);
        }
        data
    });

    let t0 = Instant::now();
    let _ = t0.elapsed();
}
