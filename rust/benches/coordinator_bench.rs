//! PERF/L3 — coordinator hot-path benchmarks without PJRT: queue
//! round-trip latency, batcher aggregation, metrics overhead, the
//! typed-router section (queue-depth max/mean over the run, joint-batch
//! split overhead, response-recycle hit rate), the bucketed-pool O(1)
//! take/put check, and the serial-vs-work-stealing joint throughput
//! comparison.  These keep the L3 overhead honest against the paper's
//! "merging overhead must not eat the savings" requirement.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use pitome::config::{ServingConfig, ViTConfig};
use pitome::coordinator::{Coordinator, CpuWorkloads, Metrics, Payload, Qos,
                          TensorPool, Workload};
use pitome::data::{generate_trace, patchify, sent_item, shape_item,
                   vqa_item, TraceConfig, TEST_SEED};
use pitome::engine::JointKind;
use pitome::model::synthetic_mm_store;
use pitome::util::{smoke, Bench};

fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 3) } else { Bench::new(3, 15) };
    println!("# coordinator micro-benchmarks (no PJRT){}",
             if sm { " [smoke]" } else { "" });
    let reps: u64 = if sm { 100 } else { 10_000 };
    let msgs: u64 = if sm { 50 } else { 1_000 };

    // metrics overhead on the hot path
    let m = Metrics::default();
    b.run_throughput(&format!("metrics.record x{reps}"), reps, || {
        for i in 0..reps {
            m.record(i % 5_000);
        }
    });

    // channel round trip (the submit/response path minus execution)
    b.run_throughput(&format!("sync_channel round-trip x{msgs}"), msgs, || {
        let (tx, rx) = mpsc::sync_channel::<u64>(1024);
        let j = std::thread::spawn(move || {
            let mut acc = 0u64;
            while let Ok(v) = rx.recv() {
                acc += v;
            }
            acc
        });
        for i in 0..msgs {
            tx.send(i).unwrap();
        }
        drop(tx);
        j.join().unwrap()
    });

    // trace generation cost (excluded from serving numbers)
    b.run(&format!("generate_trace {reps} events"), || {
        generate_trace(&TraceConfig { count: reps as usize,
                                      ..Default::default() })
            .expect("trace")
    });

    // batch assembly: stack 8 x (64x16) f32 inputs (what run_batch does)
    let sample: Vec<f32> = (0..64 * 16).map(|i| i as f32).collect();
    b.run_throughput("batch assembly 8x(64x16)", 8, || {
        let mut data = Vec::with_capacity(8 * sample.len());
        for _ in 0..8 {
            data.extend_from_slice(&sample);
        }
        data
    });

    pool_section(&mut b, sm);
    router_section(sm);
    stealing_section(sm);

    b.write_json("coordinator");
}

/// Running queue-depth statistics sampled over a serving run — the
/// per-cycle max and mean (summed across every workload queue), instead
/// of only the final drained snapshot that used to be reported and was
/// always zero by the time it printed.
#[derive(Default)]
struct DepthTrack {
    max: usize,
    sum: u64,
    n: u64,
}

impl DepthTrack {
    /// Sample the total queued depth across every variant queue.
    fn sample(&mut self, coord: &Coordinator) {
        let depth: usize = coord
            .router()
            .queue_depths()
            .iter()
            .map(|(_, _, _, d)| d)
            .sum();
        self.max = self.max.max(depth);
        self.sum += depth as u64;
        self.n += 1;
    }

    /// Report the run's max/mean depth under `label`.
    fn report(&self, label: &str) {
        let mean = self.sum as f64 / self.n.max(1) as f64;
        println!("  {label:<28} queue depth max {} mean {:.2} \
                  ({} samples)", self.max, mean, self.n);
    }
}

/// Bucketed-pool O(1) check: take/put latency of one fixed shape while
/// the pool holds 0 / 64 / 256 idle buffers in *other* capacity classes.
/// The retired best-fit freelist scanned every resident buffer per take,
/// so its latency grew with the distractor count; the bucketed pool
/// indexes the capacity class directly and these rows must stay flat.
fn pool_section(b: &mut Bench, sm: bool) {
    println!("\n# bucketed pool: take/put vs resident idle buffers (O(1) check)");
    let iters: u64 = if sm { 200 } else { 20_000 };
    for &distractors in &[0usize, 64, 256] {
        let pool = Arc::new(TensorPool::new());
        // park idle buffers across many capacity classes (none in the
        // measured class): each take below must step over none of them
        let mut held = Vec::new();
        for i in 0..distractors {
            let len = 3usize << (i % 8); // classes 2..=9
            held.push(pool.take_f32(len));
        }
        drop(held);
        // warm the measured class (len 1500 -> class 11) so steady-state
        // takes recycle from the thread-local shelf
        drop(pool.take_f32(1500));
        let name = format!("pool take/put len=1500, {distractors} idle");
        b.run_throughput(&name, iters, || {
            for _ in 0..iters {
                drop(std::hint::black_box(pool.take_f32(1500)));
            }
        });
        let (recycled, fresh) = pool.stats();
        assert!(recycled > fresh,
                "warmed take/put must recycle, not allocate \
                 ({recycled} recycled vs {fresh} fresh)");
    }
}

/// Typed-router serving section: boots the CPU multi-workload
/// coordinator on synthetic multimodal weights and reports per-workload
/// latency, queue-depth max/mean over the run, joint-batch split
/// overhead (a paired batch vs its two single-tower halves), and the
/// response-recycle hit rate.
fn router_section(sm: bool) {
    println!("\n# typed router (vision + text + joint pools, synthetic weights)");
    let reqs: usize = if sm { 12 } else { 120 };
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        vision: vec![("vit".to_string(),
                      vec![("pitome".to_string(), 0.9)])],
        text: vec![("bert".to_string(), vec![("none".to_string(), 1.0)])],
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let coord = Coordinator::boot_cpu_workloads(
        &ps, &workloads, ServingConfig::default()).expect("boot");
    let pool = coord.pool().clone();
    let slot = coord.response_slot();
    let tcfg = pitome::config::TextConfig::default();

    let item = shape_item(TEST_SEED, 0);
    let patches = patchify(&item.image, 4);
    let (question, _) = vqa_item(TEST_SEED, 0);
    let (tokens, _) = sent_item(TEST_SEED, 0, tcfg.seq_len, 16);

    let submit_vision = |i: u64| {
        let _ = i;
        let mut vt = pool.take_f32(patches.data.len());
        vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        coord.submit_pooled(Workload::Vision, "vit", Qos::Throughput,
                            Payload::Vision(vt), &slot).expect("submit");
        slot.recv().expect("vision response")
    };
    let submit_text = || {
        let mut tt = pool.take_i32(tokens.len());
        tt.fill_i32(&tokens, &[tokens.len()]);
        coord.submit_pooled(Workload::Text, "bert", Qos::Throughput,
                            Payload::Text(tt), &slot).expect("submit");
        slot.recv().expect("text response")
    };
    let submit_joint = || {
        let mut vt = pool.take_f32(patches.data.len());
        vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
        let mut qt = pool.take_i32(question.len());
        qt.fill_i32(&question, &[question.len()]);
        coord.submit_pooled(Workload::Joint, "vqa", Qos::Throughput,
                            Payload::Joint { vision: vt, text: qt }, &slot)
            .expect("submit");
        slot.recv().expect("joint response")
    };

    // warm every pool (sessions grow their buffers, freelists fill)
    for i in 0..3 {
        drop(submit_vision(i));
        drop(submit_text());
        drop(submit_joint());
    }

    // per-workload round-trip latency; the joint-vs-halves gap is the
    // split overhead (pair batches run both towers + fusion).  Queue
    // depth is sampled once per cycle and reported as max/mean over the
    // whole run — the final snapshot is always drained to zero and says
    // nothing about batching behavior.
    let lat = |label: &str, f: &mut dyn FnMut()| {
        let mut depths = DepthTrack::default();
        let t0 = Instant::now();
        for _ in 0..reqs {
            f();
            depths.sample(&coord);
        }
        let us = t0.elapsed().as_micros() as f64 / reqs as f64;
        println!("  {label:<28} {us:>10.1} us/req");
        depths.report(label);
        us
    };
    let v_us = lat("vision round-trip", &mut || drop(submit_vision(1)));
    let t_us = lat("text round-trip", &mut || drop(submit_text()));
    let j_us = lat("joint (pair) round-trip", &mut || drop(submit_joint()));
    println!("  joint split overhead: {:.1} us vs vision+text {:.1} us \
              (x{:.2})",
             j_us, v_us + t_us, j_us / (v_us + t_us).max(1.0));

    println!("  recycle hit rate: {}", pool.hit_rate_summary());
    let total: u64 = coord.metrics().iter().map(|(_, _, s)| s.count).sum();
    assert_eq!(total as usize, 3 * (reqs + 3), "router lost requests");
}

/// Mixed-workload burst throughput at 1 vs N workers: the same joint
/// request burst through a serial coordinator and a work-stealing one.
/// With `workers > 1` the joint worker drains both tower halves through
/// one stealing pool, so the burst should clear meaningfully faster than
/// the serial fan-out (and the answers are bitwise identical — asserted
/// in `engine::multimodal`'s tests, not re-proved here).
fn stealing_section(sm: bool) {
    println!("\n# joint burst: serial fan-out vs work-stealing workers");
    let bursts: usize = if sm { 2 } else { 8 };
    let pairs: usize = if sm { 8 } else { 32 };
    let ps = Arc::new(synthetic_mm_store(&ViTConfig::default(), 7));
    let workloads = CpuWorkloads {
        vision: Vec::new(),
        text: Vec::new(),
        joint: vec![("vqa".to_string(), JointKind::Vqa,
                     vec![("pitome".to_string(), 0.9)])],
        ..Default::default()
    };
    let item = shape_item(TEST_SEED, 0);
    let patches = patchify(&item.image, 4);
    let (question, _) = vqa_item(TEST_SEED, 0);
    let mut serial_us = 0.0f64;
    for workers in [1usize, 4] {
        let cfg = ServingConfig { workers, ..Default::default() };
        let coord = Coordinator::boot_cpu_workloads(&ps, &workloads, cfg)
            .expect("boot");
        let pool = coord.pool().clone();
        let burst = |depths: &mut DepthTrack| {
            let rxs: Vec<_> = (0..pairs)
                .map(|_| {
                    let mut vt = pool.take_f32(patches.data.len());
                    vt.fill_f32(&patches.data, &[patches.rows, patches.cols]);
                    let mut qt = pool.take_i32(question.len());
                    qt.fill_i32(&question, &[question.len()]);
                    let rx = coord
                        .submit_typed(Workload::Joint, "vqa",
                                      Qos::Throughput,
                                      Payload::Joint { vision: vt, text: qt })
                        .expect("submit");
                    depths.sample(&coord);
                    rx
                })
                .collect();
            for rx in rxs {
                drop(rx.recv().expect("joint response"));
            }
        };
        // warm sessions and pools outside the timed region
        burst(&mut DepthTrack::default());
        let mut depths = DepthTrack::default();
        let t0 = Instant::now();
        for _ in 0..bursts {
            burst(&mut depths);
        }
        let us =
            t0.elapsed().as_micros() as f64 / (bursts * pairs) as f64;
        let label = format!("{workers} worker(s)");
        println!("  {label:<28} {us:>10.1} us/pair");
        depths.report(&label);
        if workers == 1 {
            serial_us = us;
        } else if !sm {
            println!("  stealing speedup over serial: x{:.2}",
                     serial_us / us.max(1e-9));
        }
    }
}
