//! PERF/L3 — coordinator hot-path benchmarks without PJRT: queue
//! round-trip latency, batcher aggregation, metrics overhead.  These keep
//! the L3 overhead honest against the paper's "merging overhead must not
//! eat the savings" requirement.

use std::sync::mpsc;
use std::time::Instant;

use pitome::coordinator::Metrics;
use pitome::data::{generate_trace, TraceConfig};
use pitome::util::{smoke, Bench};

fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 3) } else { Bench::new(3, 15) };
    println!("# coordinator micro-benchmarks (no PJRT){}",
             if sm { " [smoke]" } else { "" });
    let reps: u64 = if sm { 100 } else { 10_000 };
    let msgs: u64 = if sm { 50 } else { 1_000 };

    // metrics overhead on the hot path
    let m = Metrics::default();
    b.run_throughput(&format!("metrics.record x{reps}"), reps, || {
        for i in 0..reps {
            m.record(i % 5_000);
        }
    });

    // channel round trip (the submit/response path minus execution)
    b.run_throughput(&format!("sync_channel round-trip x{msgs}"), msgs, || {
        let (tx, rx) = mpsc::sync_channel::<u64>(1024);
        let j = std::thread::spawn(move || {
            let mut acc = 0u64;
            while let Ok(v) = rx.recv() {
                acc += v;
            }
            acc
        });
        for i in 0..msgs {
            tx.send(i).unwrap();
        }
        drop(tx);
        j.join().unwrap()
    });

    // trace generation cost (excluded from serving numbers)
    b.run(&format!("generate_trace {reps} events"), || {
        generate_trace(&TraceConfig { count: reps as usize,
                                      ..Default::default() })
    });

    // batch assembly: stack 8 x (64x16) f32 inputs (what run_batch does)
    let sample: Vec<f32> = (0..64 * 16).map(|i| i as f32).collect();
    b.run_throughput("batch assembly 8x(64x16)", 8, || {
        let mut data = Vec::with_capacity(8 * sample.len());
        for _ in 0..8 {
            data.extend_from_slice(&sample);
        }
        data
    });

    let t0 = Instant::now();
    let _ = t0.elapsed();
}
