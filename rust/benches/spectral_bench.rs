//! PERF — spectral toolkit benchmarks: Jacobi eigensolver scaling and the
//! full SD(G, Gc) pipeline (the Theorem-1 experiment's cost profile),
//! timed over the scratch-reuse coarsening path.
//!
//! In smoke mode (`BENCH_SMOKE=1`, the CI configuration) the bench first
//! runs a parity gate: the scratch-based `iterative_coarsen_scratch`
//! must reproduce the historical per-step-build path (one
//! `CosineGram::build` + allocating plan builder + `apply_plan` per
//! step, kept verbatim below as `reference_coarsen`) to 1e-6 in
//! SD(G, Gc) for every algorithm before any timings are reported.

use std::collections::HashMap;

use pitome::data::Rng;
use pitome::eval::spectral::{clustered_tokens, iterative_coarsen_scratch,
                             ClusterSpec, CoarsenAlgo, CoarsenScratch,
                             Layout};
use pitome::graph::{jacobi_eigenvalues, normalized_laplacian,
                    spectral_distance, token_graph, Partition};
use pitome::merge::energy::energy_from_gram;
use pitome::merge::pitome::{ordered_bsm_plan_gram, Split};
use pitome::merge::tome::tome_plan_gram;
use pitome::merge::{apply_plan, MergePlan};
use pitome::tensor::{CosineGram, Mat};
use pitome::util::{smoke, Bench};

/// The pre-scratch coarsening pipeline, kept verbatim as the parity
/// reference: every step builds a fresh Gram and allocates its plan and
/// merged tokens.
// lint: allow(one-gram) reason=reference baseline deliberately rebuilds the Gram each level
fn reference_coarsen(kf0: &Mat, algo: CoarsenAlgo, steps: usize, k: usize,
                     margin: f32, seed: u64) -> Partition {
    let n0 = kf0.rows;
    let mut groups: Vec<usize> = (0..n0).collect();
    let mut token_group: Vec<usize> = (0..n0).collect();
    let mut kf = kf0.clone();
    let mut sizes = vec![1f32; n0];
    let mut rng = Rng::new(seed);
    for _ in 0..steps {
        if kf.rows < 2 * k + 1 {
            break;
        }
        let g = CosineGram::build(&kf);
        let plan: MergePlan = match algo {
            CoarsenAlgo::PiToMe => {
                let e = energy_from_gram(&g, margin);
                ordered_bsm_plan_gram(&g, &e, k, 0, Split::Alternate, true,
                                      &mut rng)
            }
            CoarsenAlgo::ToMe => tome_plan_gram(&g, k, 0, None),
            CoarsenAlgo::Random => {
                let e: Vec<f32> =
                    (0..kf.rows).map(|_| rng.next_f64() as f32).collect();
                ordered_bsm_plan_gram(&g, &e, k, 0, Split::Random, true,
                                      &mut rng)
            }
        };
        let mut new_token_group = Vec::with_capacity(plan.n_out());
        for &p in &plan.protect {
            new_token_group.push(token_group[p]);
        }
        for &b in &plan.b {
            new_token_group.push(token_group[b]);
        }
        for (ai, &a) in plan.a.iter().enumerate() {
            let target_group = token_group[plan.b[plan.dst[ai]]];
            let src_group = token_group[a];
            for g in groups.iter_mut() {
                if *g == src_group {
                    *g = target_group;
                }
            }
        }
        let (kf2, sizes2) = apply_plan(&kf, &sizes, &plan);
        kf = kf2;
        sizes = sizes2;
        token_group = new_token_group;
    }
    let mut remap = HashMap::new();
    let mut next = 0usize;
    let assign: Vec<usize> = groups
        .iter()
        .map(|&g| *remap.entry(g).or_insert_with(|| {
            let v = next;
            next += 1;
            v
        }))
        .collect();
    Partition::from_assign(assign)
}

fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 2) } else { Bench::new(2, 8) };
    println!("# spectral toolkit benchmarks{}", if sm { " [smoke]" } else { "" });

    let spec = ClusterSpec { sizes: vec![16, 8, 6, 2], h: 16, noise: 0.1,
                             seed: 5, layout: Layout::Interleaved };
    let (kf, _) = clustered_tokens(&spec);
    let w = token_graph(&kf);
    let mut scratch = CoarsenScratch::new();
    let mut p = Partition::identity(0);

    if sm {
        // parity gate (CI smoke): the scratch pipeline must reproduce the
        // per-step-build path before any timings are reported
        for (algo, name) in [(CoarsenAlgo::PiToMe, "pitome"),
                             (CoarsenAlgo::ToMe, "tome"),
                             (CoarsenAlgo::Random, "random")] {
            iterative_coarsen_scratch(&kf, algo, 3, 3, 0.6, 7, &mut scratch,
                                      &mut p);
            let sd_scratch = spectral_distance(&w, &p);
            let p_ref = reference_coarsen(&kf, algo, 3, 3, 0.6, 7);
            let sd_ref = spectral_distance(&w, &p_ref);
            assert!((sd_scratch - sd_ref).abs() <= 1e-6,
                    "{name}: scratch SD {sd_scratch} vs per-step-build SD \
                     {sd_ref}");
            println!("parity {name:<8} scratch SD {sd_scratch:.6} == \
                      per-step-build SD {sd_ref:.6}");
        }
    }

    let ns: &[usize] = if sm { &[16] } else { &[16, 32, 64, 128] };
    for &n in ns {
        let nspec = ClusterSpec {
            sizes: vec![n / 2, n / 4, n / 8, n - n / 2 - n / 4 - n / 8],
            h: 16,
            noise: 0.1,
            seed: 5,
            layout: Layout::Interleaved,
        };
        let (nkf, _) = clustered_tokens(&nspec);
        let nw = token_graph(&nkf);
        let nl = normalized_laplacian(&nw);
        b.run(&format!("jacobi_eigenvalues n={n}"), || {
            jacobi_eigenvalues(&nl, 1e-6, 100)
        });
    }

    b.run("coarsen only (scratch, n=32, 3 steps)", || {
        iterative_coarsen_scratch(&kf, CoarsenAlgo::PiToMe, 3, 3, 0.6, 7,
                                  &mut scratch, &mut p);
        p.n_groups
    });
    b.run("coarsen only (per-step build, n=32, 3 steps)", || {
        reference_coarsen(&kf, CoarsenAlgo::PiToMe, 3, 3, 0.6, 7).n_groups
    });
    b.run("full SD pipeline (coarsen+lift+2x eig, n=32)", || {
        iterative_coarsen_scratch(&kf, CoarsenAlgo::PiToMe, 3, 3, 0.6, 7,
                                  &mut scratch, &mut p);
        spectral_distance(&w, &p)
    });

    b.write_json("spectral");
}
