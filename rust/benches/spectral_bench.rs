//! PERF — spectral toolkit benchmarks: Jacobi eigensolver scaling and the
//! full SD(G, Gc) pipeline (the Theorem-1 experiment's cost profile).

use pitome::eval::spectral::{clustered_tokens, iterative_coarsen,
                             ClusterSpec, CoarsenAlgo, Layout};
use pitome::graph::{jacobi_eigenvalues, normalized_laplacian,
                    spectral_distance, token_graph};
use pitome::util::{smoke, Bench};

fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 2) } else { Bench::new(2, 8) };
    println!("# spectral toolkit benchmarks{}", if sm { " [smoke]" } else { "" });

    let ns: &[usize] = if sm { &[16] } else { &[16, 32, 64, 128] };
    for &n in ns {
        let spec = ClusterSpec {
            sizes: vec![n / 2, n / 4, n / 8, n - n / 2 - n / 4 - n / 8],
            h: 16,
            noise: 0.1,
            seed: 5,
            layout: Layout::Interleaved,
        };
        let (kf, _) = clustered_tokens(&spec);
        let w = token_graph(&kf);
        let l = normalized_laplacian(&w);
        b.run(&format!("jacobi_eigenvalues n={n}"), || {
            jacobi_eigenvalues(&l, 1e-6, 100)
        });
    }

    let spec = ClusterSpec { sizes: vec![16, 8, 6, 2], h: 16, noise: 0.1,
                             seed: 5, layout: Layout::Interleaved };
    let (kf, _) = clustered_tokens(&spec);
    let w = token_graph(&kf);
    b.run("full SD pipeline (coarsen+lift+2x eig, n=32)", || {
        let p = iterative_coarsen(&kf, CoarsenAlgo::PiToMe, 3, 3, 0.6, 7);
        spectral_distance(&w, &p)
    });
}
