//! PERF — embedding-gallery scan benchmarks: exact blocked-scan
//! throughput and worker-count scaling, the two-stage coarse-probe
//! speed/recall trade-off, and the warmed zero-allocation query cycle
//! (the serving-path contract, enforced with the thread-local
//! [`CountingAllocator`] hook).  The exact kernel is asserted against a
//! naive score-everything-then-full-sort reference at every size, so
//! the bench doubles as a correctness harness at scales the unit tests
//! do not reach.

use pitome::data::Rng;
use pitome::gallery::{scan_into, scan_two_stage_into, GalleryOptions,
                      GalleryScratch, GalleryStore, Hit, ScanMode};
use pitome::merge::batch::recommended_workers;
use pitome::tensor::dot;
use pitome::util::{allocs_this_thread, smoke, Bench, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Embedding dimension (matches the retrieval towers' shared space).
const DIM: usize = 64;
/// Hits per query.
const K: usize = 16;

fn main() {
    let sm = smoke();
    let mut b = if sm { Bench::new(1, 3) } else { Bench::new(2, 10) };
    println!("# gallery: blocked scan over the sharded embedding store{}",
             if sm { " [smoke]" } else { "" });
    let sizes: &[usize] = if sm { &[4_096] } else { &[20_000, 200_000] };
    for &n in sizes {
        scan_section(&mut b, n);
    }
    alloc_section(&mut b, if sm { 2_048 } else { 50_000 });
    b.write_json("gallery");
}

/// Seeded random gallery with `n` rows, bulk-ingested in bounded chunks.
fn build_store(n: usize, seed: u64) -> GalleryStore {
    let store = GalleryStore::new(DIM, GalleryOptions::default());
    let mut rng = Rng::new(seed);
    const CHUNK: usize = 8_192;
    let mut buf = vec![0f32; CHUNK.min(n.max(1)) * DIM];
    let mut done = 0usize;
    while done < n {
        let take = CHUNK.min(n - done);
        for v in buf[..take * DIM].iter_mut() {
            *v = rng.uniform(-1.0, 1.0) as f32;
        }
        store.ingest_bulk(&buf[..take * DIM]).expect("bulk ingest");
        done += take;
    }
    store
}

/// Seeded probe vector.
fn probe_for(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..DIM).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
}

/// Full-sort reference: score every stored row with the same lane-split
/// dot the scan kernel uses, sort all of them, keep the first `k`.
fn naive_topk(store: &GalleryStore, probe: &[f32], k: usize) -> Vec<Hit> {
    let mut all: Vec<Hit> = Vec::with_capacity(store.len());
    store.for_each_row(|id, row, _norm| {
        all.push(Hit { id, score: dot(probe, row) });
    });
    all.sort_unstable_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    all.truncate(k);
    all
}

/// Exact-scan correctness + worker scaling + two-stage recall at one
/// gallery size.
fn scan_section(b: &mut Bench, n: usize) {
    println!("\n# n={n} rows x {DIM} dims, k={K}");
    let store = build_store(n, 0x6A11);
    let probe = probe_for(0xBEEF ^ n as u64);
    let mut scratch = GalleryScratch::new();
    let mut out: Vec<Hit> = Vec::new();

    // correctness at scale: the blocked top-k scan must equal the
    // full-sort reference exactly (same kernel, same tie order)
    scan_into(&store, &probe, K, ScanMode::Dot, 1, &mut scratch, &mut out)
        .expect("scan");
    let exact = naive_topk(&store, &probe, K);
    assert_eq!(out, exact,
               "exact scan diverged from the full-sort reference (n={n})");

    // worker-count scaling on the same store/probe (results are
    // bitwise identical at any worker count — shard selections never
    // interact until the deterministic merge)
    let max_w = recommended_workers().max(2);
    let mut serial_ns = 0.0f64;
    for w in [1usize, max_w] {
        let name = format!("exact scan n={n} workers={w}");
        let mean = b
            .run_throughput(&name, n as u64, || {
                scan_into(&store, &probe, K, ScanMode::Dot, w,
                          &mut scratch, &mut out)
                    .expect("scan")
            })
            .mean_ns();
        if w == 1 {
            serial_ns = mean;
        } else {
            b.metric(&format!("scan_scaling_n{n}_w{max_w}"),
                     serial_ns / mean.max(1.0));
        }
    }

    // two-stage coarse probe: exact when probing every block, then
    // probe 1/8 of the blocks and report recall@K against the exact
    // selection (approximate by design — reported, not asserted)
    let stats_all = scan_two_stage_into(&store, &probe, K, usize::MAX,
                                        ScanMode::Dot, &mut scratch,
                                        &mut out)
        .expect("two-stage");
    assert_eq!(out, exact,
               "two-stage probing every block must be exact (n={n})");
    let nprobe = (stats_all.blocks_total as usize / 8).max(1);
    let name = format!("two-stage scan n={n} probe={nprobe}/{} blocks",
                       stats_all.blocks_total);
    b.run(&name, || {
        scan_two_stage_into(&store, &probe, K, nprobe, ScanMode::Dot,
                            &mut scratch, &mut out)
            .expect("two-stage")
    });
    let hit = out
        .iter()
        .filter(|h| exact.iter().any(|e| e.id == h.id))
        .count();
    b.metric(&format!("two_stage_recall_at_{K}_n{n}"),
             hit as f64 / exact.len().max(1) as f64);
}

/// The serving-path contract: a warmed query→top-k cycle performs zero
/// allocations (scratch heaps, merge cursors and the output vector are
/// all reused), so steady-state query cost is pure compute.
fn alloc_section(b: &mut Bench, n: usize) {
    println!("\n# warmed query cycle allocation audit (n={n})");
    let store = build_store(n, 0x600D);
    let probe = probe_for(0xA110C);
    let mut scratch = GalleryScratch::new();
    let mut out: Vec<Hit> = Vec::new();
    // warm: heaps size to k, cursors/blocks/out grow to steady state
    for _ in 0..3 {
        scan_into(&store, &probe, K, ScanMode::Dot, 1, &mut scratch,
                  &mut out)
            .expect("scan");
        scan_two_stage_into(&store, &probe, K, 4, ScanMode::Dot,
                            &mut scratch, &mut out)
            .expect("two-stage");
    }
    let iters = 32u64;
    let before = allocs_this_thread();
    for _ in 0..iters {
        std::hint::black_box(
            scan_into(&store, &probe, K, ScanMode::Dot, 1, &mut scratch,
                      &mut out)
                .expect("scan"));
    }
    let delta = allocs_this_thread() - before;
    assert_eq!(delta, 0,
               "warmed exact scan allocated {delta} times in {iters} \
                cycles");
    b.metric("warmed_scan_allocs_per_query", delta as f64 / iters as f64);
}
