//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment resolves no external registry (DESIGN.md §11), so
//! this vendored micro-crate implements exactly the surface the `pitome`
//! binaries and examples use: [`Result`], [`Error`], and the [`anyhow!`]
//! macro.  It is not a general replacement — no backtraces, no context
//! chains — just a string-backed error that any `std::error::Error`
//! converts into.

use std::fmt;

/// String-backed dynamic error.
///
/// Deliberately does **not** implement `std::error::Error`: that keeps the
/// blanket `From<E: std::error::Error>` impl coherent, exactly as the real
/// `anyhow::Error` does.
pub struct Error(String);

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    // `fn main() -> Result<(), E>` prints errors via Debug; show the
    // message verbatim rather than a struct dump.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macro_and_conversions() {
        let e = anyhow!("bad thing {}", 7);
        assert_eq!(e.to_string(), "bad thing 7");
        let io = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: super::Error = io.into();
        assert!(e.to_string().contains("boom"));
    }

    #[test]
    fn question_mark_from_std_error() {
        fn inner() -> super::Result<()> {
            let _: usize = "nope".parse()?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
