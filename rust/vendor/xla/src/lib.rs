//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build environment has neither the XLA shared libraries nor an
//! external crate registry, so this vendored micro-crate mirrors the small
//! API surface `pitome::runtime` uses.  Every entry point that would reach
//! PJRT returns an "unavailable" [`Error`]; the types exist so the runtime
//! layer compiles and degrades gracefully — callers already skip loudly
//! when `Engine::cpu()` fails, and serving falls back to the pure-Rust CPU
//! reference model (`Coordinator::boot_cpu`).

use std::fmt;

/// Stub error carrying a description of the unavailable operation.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT is unavailable in this build (offline xla stub)"
    )))
}

/// Element types that cross the host/device boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal (stub: holds no data).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice (stub: drops the data; a
    /// stub client can never execute, so the payload is never read).
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client.  Always fails in the stub; callers are
    /// expected to skip or fall back to the pure-Rust path.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    /// Number of attached devices (stub: none).
    pub fn device_count(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("unavailable"), "{err}");
    }

    #[test]
    fn literal_construction_is_cheap() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
